//! Compressed inter-stage links: where the paper's contribution lives.
//!
//! A link sits between pipeline stages i and i+1. During training it
//! compresses activations on the forward pass and gradients on the
//! backward pass, maintains the error-feedback state, stores activation
//! sparsity masks for the shared-index mode, and ships every message
//! through the [`Transport`]: the message departs at the producer's
//! virtual completion time (`sent_at`), contends for link bandwidth (on
//! the simulator) or crosses a real socket (tcp/uds backends), and the
//! arrival time gates when the consuming stage may start (see
//! `trainer`).
//!
//! On real backends the link materializes the actual wire-codec
//! encoding, puts those bytes on the socket, and — for the stateless
//! methods, where `decode(encode(x))` is bit-identical to the shipped
//! tensor — hands the *decoded payload* downstream, so what the
//! consumer sees genuinely crossed the wire. Error-feedback deltas
//! (EF21/AQ-SGD) transmit the true compressed-delta bytes but hand the
//! locally reconstructed tensor downstream, since reconstruction needs
//! the receiver's buffer replica (state replication is a distributed
//! protocol this repo does not model yet).
//!
//! Two execution paths produce bit-identical results (asserted by
//! integration tests): `CompressImpl::Kernel` runs the L1 Pallas
//! kernels through PJRT; `CompressImpl::Native` runs `compression::ops`.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::compression::{ops, wire, Feedback, Method, Spec};
use crate::config::CompressImpl;
use crate::coordinator::feedback::{applies_to_bwd, FeedbackState};
use crate::netsim::{Dir, Payload, Transport};
use crate::runtime::{artifacts::CompressionFiles, lit_scalar, lit_vec, Runtime};
use crate::tensor::Tensor;

pub struct CompressedLink {
    pub index: usize,
    /// Unpadded element count of tensors crossing this link.
    pub n: usize,
    /// Padded size for the kernel executables.
    pub padded: usize,
    files: CompressionFiles,
    pub fwd_state: FeedbackState,
    pub bwd_state: FeedbackState,
    /// Activation masks per in-flight microbatch (shared-index mode).
    masks: HashMap<u64, Vec<f32>>,
}

impl CompressedLink {
    pub fn new(index: usize, n: usize, padded: usize, files: CompressionFiles) -> Self {
        CompressedLink {
            index,
            n,
            padded,
            files,
            fwd_state: FeedbackState::new(),
            bwd_state: FeedbackState::new(),
            masks: HashMap::new(),
        }
    }

    /// Compress activations (forward direction) for microbatch `mb_key`
    /// and ship them through the transport; `sent_at` is the producer's
    /// virtual completion time. Returns the decompressed tensor plus its
    /// arrival time at the consumer. `train=false` applies the plain
    /// operator without touching any feedback state
    /// (inference-with-compression evals).
    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &mut self,
        rt: &Runtime,
        spec: &Spec,
        imp: CompressImpl,
        t: &Tensor,
        mb_key: u64,
        train: bool,
        net: &mut dyn Transport,
        sent_at: f64,
    ) -> Result<(Tensor, f64)> {
        self.transfer(rt, spec, imp, t, mb_key, train, Dir::Fwd, net, sent_at)
    }

    /// Compress gradients (backward direction); see [`Self::forward`].
    #[allow(clippy::too_many_arguments)]
    pub fn backward(
        &mut self,
        rt: &Runtime,
        spec: &Spec,
        imp: CompressImpl,
        t: &Tensor,
        mb_key: u64,
        train: bool,
        net: &mut dyn Transport,
        sent_at: f64,
    ) -> Result<(Tensor, f64)> {
        self.transfer(rt, spec, imp, t, mb_key, train, Dir::Bwd, net, sent_at)
    }

    /// Ship one message: send at the producer's virtual time, receive at
    /// the consumer, return (tensor, arrival).
    ///
    /// `payload` is the materialized wire encoding (present only when the
    /// backend wants real bytes; its length is then the authoritative
    /// byte count). When `roundtrip` holds, `decode(payload)` is
    /// bit-identical to `t` and the decoded frame is handed downstream,
    /// so on real backends the consumer sees exactly what crossed the
    /// socket.
    #[allow(clippy::too_many_arguments)]
    fn ship(
        &self,
        net: &mut dyn Transport,
        dir: Dir,
        mb_key: u64,
        bytes: usize,
        raw: usize,
        sent_at: f64,
        t: Tensor,
        payload: Option<Vec<u8>>,
        roundtrip: bool,
    ) -> Result<(Tensor, f64)> {
        let bytes = payload.as_ref().map_or(bytes, Vec::len);
        match &payload {
            Some(b) => net.send(self.index, dir, mb_key, Payload::Bytes(b), raw, sent_at)?,
            None => net.send(self.index, dir, mb_key, Payload::Size(bytes), raw, sent_at)?,
        };
        let msg = net
            .recv(self.index, dir, mb_key)
            .with_context(|| format!("link {}: receiving message {mb_key}", self.index))?;
        if roundtrip {
            if let Some(p) = &msg.payload {
                let data = wire::decode(p)
                    .with_context(|| format!("link {}: decoding message {mb_key}", self.index))?;
                let out = Tensor::new(t.shape().to_vec(), data)?;
                return Ok((out, msg.arrival));
            }
        }
        Ok((t, msg.arrival))
    }

    #[allow(clippy::too_many_arguments)]
    fn transfer(
        &mut self,
        rt: &Runtime,
        spec: &Spec,
        imp: CompressImpl,
        t: &Tensor,
        mb_key: u64,
        train: bool,
        dir: Dir,
        net: &mut dyn Transport,
        sent_at: f64,
    ) -> Result<(Tensor, f64)> {
        debug_assert_eq!(t.len(), self.n, "link {} tensor size", self.index);
        let raw = wire::raw_wire_bytes(self.n);
        let want = net.wants_payload();
        match spec.method {
            Method::None => {
                let payload = want.then(|| wire::encode_raw(t.data()));
                self.ship(net, dir, mb_key, raw, raw, sent_at, t.clone(), payload, true)
            }
            Method::Quant { fw_bits, bw_bits } => {
                let bits = if dir == Dir::Fwd { fw_bits } else { bw_bits };
                let out = self.quantize(rt, imp, t, bits)?;
                let bytes = wire::quant_wire_bytes(self.n, bits);
                // encode_quant(x) decodes to exactly ops::quantize(x) == out
                let payload = want.then(|| wire::encode_quant(t.data(), bits));
                self.ship(net, dir, mb_key, bytes, raw, sent_at, out, payload, true)
            }
            Method::TopK { frac, shared_idx, feedback } => {
                let fb = if train { feedback } else { Feedback::None };
                let fb = if dir == Dir::Bwd && !applies_to_bwd(fb) { Feedback::None } else { fb };
                // shared-index mode: the gradient reuses the activation
                // mask captured on this microbatch's forward pass
                if dir == Dir::Bwd && shared_idx && train {
                    let mask = self
                        .masks
                        .remove(&mb_key)
                        .with_context(|| format!("link {}: no stored mask for mb {mb_key}", self.index))?;
                    let out = self.apply_mask(rt, imp, t, &mask)?;
                    let k = out.count_nonzero();
                    let bytes = wire::sparse_wire_bytes(self.n, k);
                    let payload = want.then(|| wire::encode_sparse(out.data(), k));
                    return self.ship(net, dir, mb_key, bytes, raw, sent_at, out, payload, true);
                }
                // `delta_msg`, when set, is the dense form of the message
                // that actually crosses the wire (EF21/AQ-SGD deltas); the
                // receiver would reconstruct `out` against its buffer.
                let (out, k_on_wire, delta_msg) = match fb {
                    Feedback::None => {
                        let thresh = ops::threshold_for_frac(t.data(), frac);
                        let (xhat, mask) = self.topk(rt, imp, t, thresh)?;
                        if dir == Dir::Fwd && shared_idx && train {
                            self.masks.insert(mb_key, mask);
                        }
                        let k = xhat.count_nonzero();
                        (xhat, k, None)
                    }
                    Feedback::Ef => {
                        let (c, k) = self.ef_step(rt, imp, t, frac, dir)?;
                        (c, k, None)
                    }
                    Feedback::EfMixed => {
                        let (c, k) = self.efmixed_step(t, frac, dir)?;
                        (c, k, None)
                    }
                    Feedback::Ef21 => self.ef21_step(rt, imp, t, frac, dir, None, want)?,
                    Feedback::AqSgd => {
                        debug_assert_eq!(dir, Dir::Fwd);
                        match self.fwd_state.sample(mb_key).cloned() {
                            None => {
                                // bootstrap: first visit sends uncompressed
                                self.fwd_state.set_sample(mb_key, t.clone());
                                let payload = want.then(|| wire::encode_raw(t.data()));
                                return self.ship(
                                    net, dir, mb_key, raw, raw, sent_at, t.clone(), payload, true,
                                );
                            }
                            Some(buf) => {
                                self.ef21_step(rt, imp, t, frac, dir, Some((mb_key, buf)), want)?
                            }
                        }
                    }
                };
                let bytes = wire::sparse_wire_bytes(self.n, k_on_wire);
                let (payload, roundtrip) = match delta_msg {
                    // delta on the wire, locally reconstructed tensor downstream
                    Some(d) => (want.then(|| wire::encode_sparse(&d, k_on_wire)), false),
                    // the message IS the tensor: decode(encode) == out exactly
                    None => (want.then(|| wire::encode_sparse(out.data(), k_on_wire)), true),
                };
                self.ship(net, dir, mb_key, bytes, raw, sent_at, out, payload, roundtrip)
            }
        }
    }

    // ---- operator backends --------------------------------------------------

    fn quantize(&self, rt: &Runtime, imp: CompressImpl, t: &Tensor, bits: u8) -> Result<Tensor> {
        match imp {
            CompressImpl::Native => {
                Tensor::new(t.shape().to_vec(), ops::quantize(t.data(), bits))
            }
            CompressImpl::Kernel => {
                let padded = t.padded_flat(self.padded_block());
                let levels = (1u32 << bits) as f32;
                let out = rt.call(&self.files.quant, &[lit_vec(&padded), lit_scalar(levels)])?;
                Tensor::from_padded(t.shape(), &out[0].to_vec::<f32>()?)
            }
        }
    }

    fn topk(
        &self,
        rt: &Runtime,
        imp: CompressImpl,
        t: &Tensor,
        thresh: f32,
    ) -> Result<(Tensor, Vec<f32>)> {
        match imp {
            CompressImpl::Native => {
                let (xh, mask) = ops::apply_threshold(t.data(), thresh);
                Ok((Tensor::new(t.shape().to_vec(), xh)?, mask))
            }
            CompressImpl::Kernel => {
                let padded = t.padded_flat(self.padded_block());
                let out = rt.call(&self.files.topk, &[lit_vec(&padded), lit_scalar(thresh)])?;
                let xh = Tensor::from_padded(t.shape(), &out[0].to_vec::<f32>()?)?;
                let mut mask = out[1].to_vec::<f32>()?;
                mask.truncate(self.n);
                Ok((xh, mask))
            }
        }
    }

    fn apply_mask(
        &self,
        rt: &Runtime,
        imp: CompressImpl,
        t: &Tensor,
        mask: &[f32],
    ) -> Result<Tensor> {
        match imp {
            CompressImpl::Native => {
                Tensor::new(t.shape().to_vec(), ops::mask_apply(t.data(), mask))
            }
            CompressImpl::Kernel => {
                let padded = t.padded_flat(self.padded_block());
                // pad the mask with zeros (padding lanes must stay dropped)
                let mut m = mask.to_vec();
                m.resize(self.padded, 0.0);
                let out = rt.call(&self.files.mask, &[lit_vec(&padded), lit_vec(&m)])?;
                Tensor::from_padded(t.shape(), &out[0].to_vec::<f32>()?)
            }
        }
    }

    /// Classic EF: c = C(x + e), e' = x + e - c.
    fn ef_step(
        &mut self,
        rt: &Runtime,
        imp: CompressImpl,
        t: &Tensor,
        frac: f32,
        dir: Dir,
    ) -> Result<(Tensor, usize)> {
        let state = self.state_mut(dir);
        let buf = state.global_mut(t.len()).clone();
        // threshold over s = x + e (host: the selection is the
        // coordinator's job in both paths; see DESIGN.md §2)
        let s: Vec<f32> = t.data().iter().zip(buf.data()).map(|(a, b)| a + b).collect();
        let thresh = ops::threshold_for_frac(&s, frac);
        let (c, e_new) = match imp {
            CompressImpl::Native => {
                let (c, e) = ops::ef_combine(t.data(), buf.data(), frac);
                (c, e)
            }
            CompressImpl::Kernel => {
                let xp = t.padded_flat(self.padded_block());
                let mut ep = buf.data().to_vec();
                // pad the buffer with zeros: padding lanes of x replicate
                // the last element and must not leak into the state
                ep.resize(self.padded, 0.0);
                let out =
                    rt.call(&self.files.ef_combine, &[lit_vec(&xp), lit_vec(&ep), lit_scalar(thresh)])?;
                let mut c = out[0].to_vec::<f32>()?;
                let mut e = out[1].to_vec::<f32>()?;
                c.truncate(self.n);
                e.truncate(self.n);
                (c, e)
            }
        };
        let k = c.iter().filter(|&&v| v != 0.0).count();
        self.state_mut(dir).set_global(Tensor::new(vec![t.len()], e_new)?);
        Ok((Tensor::new(t.shape().to_vec(), c)?, k))
    }

    /// EF-mixed: K/2 budget on x, K/2 on the buffer (native-only math,
    /// composed from two mask kernels in the kernel path).
    fn efmixed_step(&mut self, t: &Tensor, frac: f32, dir: Dir) -> Result<(Tensor, usize)> {
        let state = self.state_mut(dir);
        let buf = state.global_mut(t.len()).clone();
        let (msg, e_new) = ops::ef_mixed(t.data(), buf.data(), frac);
        let k = msg.iter().filter(|&&v| v != 0.0).count();
        self.state_mut(dir).set_global(Tensor::new(vec![t.len()], e_new)?);
        Ok((Tensor::new(t.shape().to_vec(), msg)?, k))
    }

    /// EF21 (global buffer) or AQ-SGD (per-sample buffer) delta step.
    /// When `want_delta` holds, also returns the dense masked delta —
    /// the message a real wire carries (the receiver reconstructs
    /// against its buffer replica).
    #[allow(clippy::too_many_arguments)]
    fn ef21_step(
        &mut self,
        rt: &Runtime,
        imp: CompressImpl,
        t: &Tensor,
        frac: f32,
        dir: Dir,
        sample: Option<(u64, Tensor)>,
        want_delta: bool,
    ) -> Result<(Tensor, usize, Option<Vec<f32>>)> {
        let buf = match &sample {
            Some((_, b)) => b.clone(),
            None => self.state_mut(dir).global_mut(t.len()).clone(),
        };
        let delta: Vec<f32> = t.data().iter().zip(buf.data()).map(|(a, b)| a - b).collect();
        let thresh = ops::threshold_for_frac(&delta, frac);
        // exact-zero delta elements are never encoded (the codec skips
        // them even when thresh == 0), so don't charge them either —
        // keeps sim-charged bytes == real payload length on all backends
        let k = delta.iter().filter(|&&d| d != 0.0 && d.abs() >= thresh).count();
        let delta_msg = want_delta.then(|| {
            delta
                .iter()
                .map(|&d| if d.abs() >= thresh { d } else { 0.0 })
                .collect::<Vec<f32>>()
        });
        let xhat = match imp {
            CompressImpl::Native => {
                let (xh, _) = ops::ef21_step(t.data(), buf.data(), frac);
                Tensor::new(t.shape().to_vec(), xh)?
            }
            CompressImpl::Kernel => {
                let xp = t.padded_flat(self.padded_block());
                let mut gp = buf.data().to_vec();
                let fill = buf.data().last().copied().unwrap_or(0.0);
                gp.resize(self.padded, fill);
                let out =
                    rt.call(&self.files.delta_topk, &[lit_vec(&xp), lit_vec(&gp), lit_scalar(thresh)])?;
                Tensor::from_padded(t.shape(), &out[0].to_vec::<f32>()?)?
            }
        };
        let flat = Tensor::new(vec![t.len()], xhat.data().to_vec())?;
        match sample {
            Some((key, _)) => self.fwd_state.set_sample(key, flat),
            None => self.state_mut(dir).set_global(flat),
        }
        Ok((xhat, k, delta_msg))
    }

    fn state_mut(&mut self, dir: Dir) -> &mut FeedbackState {
        match dir {
            Dir::Fwd => &mut self.fwd_state,
            Dir::Bwd => &mut self.bwd_state,
        }
    }

    fn padded_block(&self) -> usize {
        self.padded
    }

    /// Reset all feedback state + masks (between runs).
    pub fn reset(&mut self) {
        self.fwd_state.reset();
        self.bwd_state.reset();
        self.masks.clear();
    }

    /// Total feedback memory (paper's AQ-SGD footprint concern).
    pub fn feedback_memory_bytes(&self) -> usize {
        self.fwd_state.memory_bytes() + self.bwd_state.memory_bytes()
    }
}
