//! Compressed inter-stage links: where the paper's contribution lives.
//!
//! A link sits between pipeline stages i and i+1. During training it
//! compresses activations on the forward pass and gradients on the
//! backward pass, maintains the error-feedback state, stores activation
//! sparsity masks for the shared-index mode, and ships every message
//! through the [`Transport`]: the message departs at the producer's
//! virtual completion time (`sent_at`), contends for link bandwidth (on
//! the simulator) or crosses a real socket (tcp/uds backends), and the
//! arrival time gates when the consuming stage may start (see
//! `trainer`).
//!
//! The link materializes the actual wire-codec encoding and hands the
//! *decoded* frame downstream, so what the consumer sees genuinely
//! crossed the wire (on real backends; the simulator charges the same
//! bytes and decodes the local copy). For the stateless methods
//! `decode(encode(x))` is bit-identical to the shipped tensor. For
//! EF21/AQ-SGD the protocol is two-sided ([`feedback`]): only the
//! compressed delta frame crosses the wire, the link's **receiver
//! mirror** applies `g += C(x-g)` (or the per-sample AQ-SGD update)
//! locally, and the frame's generation counter + buffer digest turn any
//! divergence into a typed decode-time error instead of silently
//! corrupted training.
//!
//! Two execution paths produce bit-identical results (asserted by
//! integration tests): `CompressImpl::Kernel` runs the L1 Pallas
//! kernels through PJRT; `CompressImpl::Native` runs `compression::ops`.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::compression::{ops, wire, Feedback, Method, Spec};
use crate::config::CompressImpl;
use crate::coordinator::feedback::{self, applies_to_bwd, FeedbackState};
use crate::netsim::{Dir, Payload, Transport};
use crate::runtime::{artifacts::CompressionFiles, lit_scalar, lit_vec, Runtime};
use crate::tensor::Tensor;

/// One compressed channel between adjacent model stages (a pipeline
/// boundary), carrying its own compression + feedback state and routed
/// over a physical wire link.
pub struct CompressedLink {
    /// Boundary index: this link connects model stages `index` and
    /// `index + 1`.
    pub index: usize,
    /// Physical transport link this boundary's messages ride on. Equal
    /// to `index` on a flat chain; with interleaved schedules several
    /// boundaries share one ring link (`index % n_ranks`) and contend
    /// for its bandwidth while keeping separate channel state here.
    pub wire_link: usize,
    /// Unpadded element count of tensors crossing this link.
    pub n: usize,
    /// Padded size for the kernel executables.
    pub padded: usize,
    files: CompressionFiles,
    /// Sender half of the forward (activation) channel's feedback state.
    pub fwd_state: FeedbackState,
    /// Sender half of the backward (gradient) channel's feedback state.
    pub bwd_state: FeedbackState,
    /// Receiver halves of the EF21/AQ-SGD protocol: mirrors of the
    /// peer's sender state, advanced only by decoding delta frames.
    pub fwd_mirror: FeedbackState,
    /// Backward-direction receiver mirror (see [`Self::fwd_mirror`]).
    pub bwd_mirror: FeedbackState,
    /// Activation masks per in-flight microbatch (shared-index mode).
    masks: HashMap<u64, Vec<f32>>,
}

impl CompressedLink {
    /// A fresh link for boundary `index`, shipping over `wire_link`.
    pub fn new(
        index: usize,
        wire_link: usize,
        n: usize,
        padded: usize,
        files: CompressionFiles,
    ) -> Self {
        CompressedLink {
            index,
            wire_link,
            n,
            padded,
            files,
            fwd_state: FeedbackState::new(),
            bwd_state: FeedbackState::new(),
            fwd_mirror: FeedbackState::new(),
            bwd_mirror: FeedbackState::new(),
            masks: HashMap::new(),
        }
    }

    /// Compress activations (forward direction) for microbatch `mb_key`
    /// and ship them through the transport; `sent_at` is the producer's
    /// virtual completion time. Returns the decompressed tensor plus its
    /// arrival time at the consumer. `train=false` applies the plain
    /// operator without touching any feedback state
    /// (inference-with-compression evals).
    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &mut self,
        rt: &Runtime,
        spec: &Spec,
        imp: CompressImpl,
        t: &Tensor,
        mb_key: u64,
        train: bool,
        net: &mut dyn Transport,
        sent_at: f64,
    ) -> Result<(Tensor, f64)> {
        self.transfer(rt, spec, imp, t, mb_key, train, Dir::Fwd, net, sent_at)
    }

    /// Compress gradients (backward direction); see [`Self::forward`].
    #[allow(clippy::too_many_arguments)]
    pub fn backward(
        &mut self,
        rt: &Runtime,
        spec: &Spec,
        imp: CompressImpl,
        t: &Tensor,
        mb_key: u64,
        train: bool,
        net: &mut dyn Transport,
        sent_at: f64,
    ) -> Result<(Tensor, f64)> {
        self.transfer(rt, spec, imp, t, mb_key, train, Dir::Bwd, net, sent_at)
    }

    /// Ship one stateless message: send at the producer's virtual time,
    /// receive at the consumer, return (tensor, arrival).
    ///
    /// `payload` is the materialized wire encoding (present only when the
    /// backend wants real bytes; its length is then the authoritative
    /// byte count). The codecs here are exact — `decode(payload)` is
    /// bit-identical to `t` — so when a payload crossed a real socket
    /// the decoded frame is handed downstream.
    #[allow(clippy::too_many_arguments)]
    fn ship(
        &self,
        net: &mut dyn Transport,
        dir: Dir,
        mb_key: u64,
        bytes: usize,
        raw: usize,
        sent_at: f64,
        t: Tensor,
        payload: Option<Vec<u8>>,
    ) -> Result<(Tensor, f64)> {
        let bytes = payload.as_ref().map_or(bytes, Vec::len);
        match &payload {
            Some(b) => net.send(self.wire_link, dir, mb_key, Payload::Bytes(b), raw, sent_at)?,
            None => net.send(self.wire_link, dir, mb_key, Payload::Size(bytes), raw, sent_at)?,
        };
        let msg = net
            .recv(self.wire_link, dir, mb_key)
            .with_context(|| format!("link {}: receiving message {mb_key}", self.index))?;
        if let Some(p) = &msg.payload {
            let dec_t = crate::telemetry::timer();
            let data = wire::decode(p)
                .with_context(|| format!("link {}: decoding message {mb_key}", self.index))?;
            dec_t.stop(
                crate::telemetry::span::codec_track(self.wire_link),
                "decode",
                "codec",
                mb_key,
            );
            let out = Tensor::new(t.shape().to_vec(), data)?;
            return Ok((out, msg.arrival));
        }
        Ok((t, msg.arrival))
    }

    #[allow(clippy::too_many_arguments)]
    fn transfer(
        &mut self,
        rt: &Runtime,
        spec: &Spec,
        imp: CompressImpl,
        t: &Tensor,
        mb_key: u64,
        train: bool,
        dir: Dir,
        net: &mut dyn Transport,
        sent_at: f64,
    ) -> Result<(Tensor, f64)> {
        debug_assert_eq!(t.len(), self.n, "link {} tensor size", self.index);
        // attribute this boundary's transport counters to its channel
        crate::telemetry::set_channel_hint(self.index as u32);
        let raw = wire::raw_wire_bytes(self.n);
        let want = net.wants_payload();
        // one wall-clock codec span per message: operator + wire encode
        // (the delta protocol's branch records its own)
        let track = crate::telemetry::span::codec_track(self.wire_link);
        let enc_t = crate::telemetry::timer();
        match spec.method {
            Method::None => {
                let payload = want.then(|| wire::encode_raw(t.data()));
                enc_t.stop(track, "encode", "codec", mb_key);
                self.ship(net, dir, mb_key, raw, raw, sent_at, t.clone(), payload)
            }
            Method::Quant { fw_bits, bw_bits } => {
                let bits = if dir == Dir::Fwd { fw_bits } else { bw_bits };
                let out = self.quantize(rt, imp, t, bits)?;
                let bytes = wire::quant_wire_bytes(self.n, bits);
                // encode_quant(x) decodes to exactly ops::quantize(x) == out
                let payload = want.then(|| wire::encode_quant(t.data(), bits));
                enc_t.stop(track, "encode", "codec", mb_key);
                self.ship(net, dir, mb_key, bytes, raw, sent_at, out, payload)
            }
            Method::TopK { frac, shared_idx, feedback } => {
                let fb = if train { feedback } else { Feedback::None };
                let fb = if dir == Dir::Bwd && !applies_to_bwd(fb) { Feedback::None } else { fb };
                // shared-index mode: the gradient reuses the activation
                // mask captured on this microbatch's forward pass
                if dir == Dir::Bwd && shared_idx && train {
                    let mask = self
                        .masks
                        .remove(&mb_key)
                        .with_context(|| format!("link {}: no stored mask for mb {mb_key}", self.index))?;
                    let out = self.apply_mask(rt, imp, t, &mask)?;
                    let k = out.count_nonzero();
                    let bytes = wire::sparse_wire_bytes(self.n, k);
                    let payload = want.then(|| wire::encode_sparse(out.data(), k));
                    enc_t.stop(track, "encode", "codec", mb_key);
                    return self.ship(net, dir, mb_key, bytes, raw, sent_at, out, payload);
                }
                // two-sided delta protocol: only the compressed delta
                // crosses the wire, the receiver mirror reconstructs
                if feedback::uses_delta_frames(fb) {
                    return self.delta_transfer(rt, imp, t, frac, fb, mb_key, dir, net, sent_at);
                }
                let (out, k_on_wire) = match fb {
                    Feedback::None => {
                        let thresh = ops::threshold_for_frac(t.data(), frac);
                        let (xhat, mask) = self.topk(rt, imp, t, thresh)?;
                        if dir == Dir::Fwd && shared_idx && train {
                            self.masks.insert(mb_key, mask);
                        }
                        let k = xhat.count_nonzero();
                        (xhat, k)
                    }
                    Feedback::Ef => self.ef_step(rt, imp, t, frac, dir)?,
                    Feedback::EfMixed => self.efmixed_step(t, frac, dir)?,
                    Feedback::Ef21 | Feedback::AqSgd => unreachable!("delta protocol"),
                };
                let bytes = wire::sparse_wire_bytes(self.n, k_on_wire);
                // the message IS the tensor: decode(encode) == out exactly
                let payload = want.then(|| wire::encode_sparse(out.data(), k_on_wire));
                enc_t.stop(track, "encode", "codec", mb_key);
                self.ship(net, dir, mb_key, bytes, raw, sent_at, out, payload)
            }
        }
    }

    /// EF21/AQ-SGD transfer: run the sender half against this link's
    /// feedback state (kernel or native), put the actual delta frame on
    /// the transport, and hand downstream what the **receiver mirror**
    /// reconstructs from the decoded frame — generation and digest
    /// checked, so sender/receiver divergence fails loudly here.
    #[allow(clippy::too_many_arguments)]
    fn delta_transfer(
        &mut self,
        rt: &Runtime,
        imp: CompressImpl,
        t: &Tensor,
        frac: f32,
        fb: Feedback,
        mb_key: u64,
        dir: Dir,
        net: &mut dyn Transport,
        sent_at: f64,
    ) -> Result<(Tensor, f64)> {
        debug_assert!(fb != Feedback::AqSgd || dir == Dir::Fwd, "AQ-SGD is activations-only");
        let track = crate::telemetry::span::codec_track(self.wire_link);
        let enc_t = crate::telemetry::timer();
        let frame = match imp {
            // the native path IS the shared state machine
            CompressImpl::Native => {
                self.state_mut(dir).sender_encode(fb, mb_key, t.data(), frac)?.0
            }
            CompressImpl::Kernel => {
                // bootstrap frames carry the raw tensor — no kernel runs,
                // so the shared state machine handles the first visit
                if fb == Feedback::AqSgd && self.fwd_state.sample(mb_key).is_none() {
                    self.fwd_state.sender_encode(fb, mb_key, t.data(), frac)?.0
                } else {
                    let buf = match fb {
                        Feedback::AqSgd => {
                            self.fwd_state.sample(mb_key).expect("bootstrap handled").clone()
                        }
                        _ => self.state_mut(dir).global_mut(t.len()).clone(),
                    };
                    let delta: Vec<f32> =
                        t.data().iter().zip(buf.data()).map(|(a, b)| a - b).collect();
                    let thresh = ops::threshold_for_frac(&delta, frac);
                    let (delta_msg, k) = feedback::mask_delta(&delta, thresh);
                    // the pallas kernel produces the sender's new buffer;
                    // padding lanes are truncated away before the digest
                    let xp = t.padded_flat(self.padded_block());
                    let mut gp = buf.data().to_vec();
                    let fill = buf.data().last().copied().unwrap_or(0.0);
                    gp.resize(self.padded, fill);
                    let out = rt.call(
                        &self.files.delta_topk,
                        &[lit_vec(&xp), lit_vec(&gp), lit_scalar(thresh)],
                    )?;
                    let mut xhat = out[0].to_vec::<f32>()?;
                    xhat.truncate(self.n);
                    let digest = feedback::buffer_digest(&xhat);
                    let state = self.state_mut_for(fb, dir);
                    let gen = state.next_gen();
                    let flat = Tensor::from_vec(xhat);
                    match fb {
                        Feedback::AqSgd => state.set_sample(mb_key, flat),
                        _ => state.set_global(flat),
                    }
                    let tag = if fb == Feedback::AqSgd { wire::FB_AQSGD } else { wire::FB_EF21 };
                    wire::encode_delta(tag, gen, mb_key, digest, &delta_msg, k)
                }
            }
        };
        enc_t.stop(track, "encode", "codec", mb_key);
        let (index, wire_link, n) = (self.index, self.wire_link, self.n);
        let raw = wire::raw_wire_bytes(n);
        net.send(wire_link, dir, mb_key, Payload::Bytes(&frame), raw, sent_at)?;
        let msg = net
            .recv(wire_link, dir, mb_key)
            .with_context(|| format!("link {index}: receiving message {mb_key}"))?;
        // real backends deliver the socket bytes; the simulator charged
        // the same frame and the local copy stands in for the wire image
        let bytes = msg.payload.as_deref().unwrap_or(&frame);
        let dec_t = crate::telemetry::timer();
        let df = wire::decode_delta(bytes)
            .with_context(|| format!("link {index}: decoding delta frame {mb_key}"))?;
        dec_t.stop(track, "decode", "codec", mb_key);
        let mirror = match dir {
            Dir::Fwd => &mut self.fwd_mirror,
            Dir::Bwd => &mut self.bwd_mirror,
        };
        let apply_t = crate::telemetry::timer();
        let recon = mirror
            .apply_frame(fb, &df, n)
            .with_context(|| format!("link {index} {dir}: applying delta frame {mb_key}"))?;
        apply_t.stop(track, "apply", "codec", mb_key);
        Ok((Tensor::new(t.shape().to_vec(), recon)?, msg.arrival))
    }

    // ---- operator backends --------------------------------------------------

    fn quantize(&self, rt: &Runtime, imp: CompressImpl, t: &Tensor, bits: u8) -> Result<Tensor> {
        match imp {
            CompressImpl::Native => {
                Tensor::new(t.shape().to_vec(), ops::quantize(t.data(), bits))
            }
            CompressImpl::Kernel => {
                let padded = t.padded_flat(self.padded_block());
                let levels = (1u32 << bits) as f32;
                let out = rt.call(&self.files.quant, &[lit_vec(&padded), lit_scalar(levels)])?;
                Tensor::from_padded(t.shape(), &out[0].to_vec::<f32>()?)
            }
        }
    }

    fn topk(
        &self,
        rt: &Runtime,
        imp: CompressImpl,
        t: &Tensor,
        thresh: f32,
    ) -> Result<(Tensor, Vec<f32>)> {
        match imp {
            CompressImpl::Native => {
                let (xh, mask) = ops::apply_threshold(t.data(), thresh);
                Ok((Tensor::new(t.shape().to_vec(), xh)?, mask))
            }
            CompressImpl::Kernel => {
                let padded = t.padded_flat(self.padded_block());
                let out = rt.call(&self.files.topk, &[lit_vec(&padded), lit_scalar(thresh)])?;
                let xh = Tensor::from_padded(t.shape(), &out[0].to_vec::<f32>()?)?;
                let mut mask = out[1].to_vec::<f32>()?;
                mask.truncate(self.n);
                Ok((xh, mask))
            }
        }
    }

    fn apply_mask(
        &self,
        rt: &Runtime,
        imp: CompressImpl,
        t: &Tensor,
        mask: &[f32],
    ) -> Result<Tensor> {
        match imp {
            CompressImpl::Native => {
                Tensor::new(t.shape().to_vec(), ops::mask_apply(t.data(), mask))
            }
            CompressImpl::Kernel => {
                let padded = t.padded_flat(self.padded_block());
                // pad the mask with zeros (padding lanes must stay dropped)
                let mut m = mask.to_vec();
                m.resize(self.padded, 0.0);
                let out = rt.call(&self.files.mask, &[lit_vec(&padded), lit_vec(&m)])?;
                Tensor::from_padded(t.shape(), &out[0].to_vec::<f32>()?)
            }
        }
    }

    /// Classic EF: c = C(x + e), e' = x + e - c.
    fn ef_step(
        &mut self,
        rt: &Runtime,
        imp: CompressImpl,
        t: &Tensor,
        frac: f32,
        dir: Dir,
    ) -> Result<(Tensor, usize)> {
        let state = self.state_mut(dir);
        let buf = state.global_mut(t.len()).clone();
        // threshold over s = x + e (host: the selection is the
        // coordinator's job in both paths; see DESIGN.md §2)
        let s: Vec<f32> = t.data().iter().zip(buf.data()).map(|(a, b)| a + b).collect();
        let thresh = ops::threshold_for_frac(&s, frac);
        let (c, e_new) = match imp {
            CompressImpl::Native => {
                let (c, e) = ops::ef_combine(t.data(), buf.data(), frac);
                (c, e)
            }
            CompressImpl::Kernel => {
                let xp = t.padded_flat(self.padded_block());
                let mut ep = buf.data().to_vec();
                // pad the buffer with zeros: padding lanes of x replicate
                // the last element and must not leak into the state
                ep.resize(self.padded, 0.0);
                let out =
                    rt.call(&self.files.ef_combine, &[lit_vec(&xp), lit_vec(&ep), lit_scalar(thresh)])?;
                let mut c = out[0].to_vec::<f32>()?;
                let mut e = out[1].to_vec::<f32>()?;
                c.truncate(self.n);
                e.truncate(self.n);
                (c, e)
            }
        };
        let k = c.iter().filter(|&&v| v != 0.0).count();
        self.state_mut(dir).set_global(Tensor::new(vec![t.len()], e_new)?);
        Ok((Tensor::new(t.shape().to_vec(), c)?, k))
    }

    /// EF-mixed: K/2 budget on x, K/2 on the buffer (native-only math,
    /// composed from two mask kernels in the kernel path).
    fn efmixed_step(&mut self, t: &Tensor, frac: f32, dir: Dir) -> Result<(Tensor, usize)> {
        let state = self.state_mut(dir);
        let buf = state.global_mut(t.len()).clone();
        let (msg, e_new) = ops::ef_mixed(t.data(), buf.data(), frac);
        let k = msg.iter().filter(|&&v| v != 0.0).count();
        self.state_mut(dir).set_global(Tensor::new(vec![t.len()], e_new)?);
        Ok((Tensor::new(t.shape().to_vec(), msg)?, k))
    }

    fn state_mut(&mut self, dir: Dir) -> &mut FeedbackState {
        match dir {
            Dir::Fwd => &mut self.fwd_state,
            Dir::Bwd => &mut self.bwd_state,
        }
    }

    /// Sender state for a delta-protocol mode: AQ-SGD buffers live on
    /// the forward state (activations only); EF21 is per-direction.
    fn state_mut_for(&mut self, fb: Feedback, dir: Dir) -> &mut FeedbackState {
        match fb {
            Feedback::AqSgd => &mut self.fwd_state,
            _ => self.state_mut(dir),
        }
    }

    fn padded_block(&self) -> usize {
        self.padded
    }

    /// Reset all feedback state (both halves) + masks (between runs).
    pub fn reset(&mut self) {
        self.fwd_state.reset();
        self.bwd_state.reset();
        self.fwd_mirror.reset();
        self.bwd_mirror.reset();
        self.masks.clear();
    }

    /// Total feedback memory, sender buffers plus receiver mirrors (the
    /// paper's AQ-SGD footprint concern — doubled by the two-sided
    /// protocol, which is exactly what this metric should show).
    pub fn feedback_memory_bytes(&self) -> usize {
        self.fwd_state.memory_bytes()
            + self.bwd_state.memory_bytes()
            + self.fwd_mirror.memory_bytes()
            + self.bwd_mirror.memory_bytes()
    }
}
