//! `mpcomp worker` — run one pipeline rank as its own OS process,
//! exchanging real compressed activations/gradients over the socket
//! transport.
//!
//! Each rank walks the same {GPipe, 1F1B, interleaved} schedule
//! (optionally repeated for `steps` rounds) and executes only its own
//! ops: a forward op receives the activation frame of its chunk's
//! upstream boundary (blocking on the real mailbox) and sends the
//! chunk's output activation downstream; a backward op receives the
//! gradient frame from the downstream boundary and sends upstream.
//! With `--virtual-stages v` (`schedule = interleaved:v`) every rank
//! hosts `v` model chunks, the wire becomes a *ring* (the last rank's
//! chunk output wraps to rank 0), and boundaries sharing a physical
//! link are distinguished by chunk-qualified message keys and
//! per-channel protocol state. Message tensors are generated
//! deterministically from `(seed, link, dir, chunk, mb)` and compressed
//! with the configured spec through the actual wire codecs, so the
//! bytes on the socket are exactly what the trainer's links would ship
//! — without needing the AOT artifacts, which makes the multi-process
//! path runnable everywhere (including the CI `loopback` job).
//!
//! Error-feedback specs run the full two-sided protocol: every rank
//! keeps sender [`FeedbackState`]s for the channels it produces and
//! **receiver mirrors** for the channels it consumes; EF21/AQ-SGD
//! frames carry only the compressed delta, and each received frame is
//! applied to the mirror (generation + digest verified) before it
//! counts as delivered. Repeating the schedule (`steps > 1`) exercises
//! the AQ-SGD bootstrap-then-update path and is what makes the measured
//! per-mailbox EF traffic drop below the plain-TopK baseline
//! ([`compare_bytes`], pinned in CI).
//!
//! Every run produces a [`WorkerSummary`]: per-`(link, dir)` mailbox
//! logs of `(key, bytes, payload digest)` in delivery order plus sent
//! totals. [`run_reference`] produces the same summary from a
//! single-process `SimNet` replay, and [`check`] asserts a set of
//! worker summaries is bit-identical to it — same per-mailbox message
//! ordering, byte counts, and payload digests — which is the sim/real
//! parity contract CI enforces across two OS processes.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::compression::{ops, wire, Feedback, Method, Spec};
use crate::config::{Schedule, ServeKnobs, WireOpts};
use crate::coordinator::allreduce::ReplicaRing;
use crate::coordinator::feedback::{applies_to_bwd, FeedbackState};
use crate::coordinator::pipeline;
use crate::coordinator::serve;
use crate::netsim::{
    arrivals, Backend, Dir, Payload, RealTransport, Rendezvous, SimNet, Transport, UdpFaults,
    UdpTransport,
};
use crate::planner::Plan;
use crate::util::json::Json;
use crate::util::rng::Rng;

pub use crate::util::fnv1a;

/// Parameters of one synthetic multi-process schedule run.
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// World size: one process per rank. With an interleaved schedule
    /// each rank hosts `schedule.chunks()` model chunks.
    pub stages: usize,
    /// Microbatches per schedule round.
    pub mb: usize,
    /// Elements per inter-stage tensor.
    pub link_elems: usize,
    /// The pipeline schedule every rank walks (its `chunks()` sets the
    /// virtual-stage count and thereby the chain-vs-ring topology).
    pub schedule: Schedule,
    /// Compression spec, including error-feedback modes (shared-index
    /// masks are a trainer concern and stay rejected). With a `plan`
    /// this is only the fallback label; the plan's per-channel specs
    /// govern the wire.
    pub spec: Spec,
    /// Per-boundary compression plan (`--plan file.json`). `None`: the
    /// single `spec` on every channel, exactly the legacy behavior.
    pub plan: Option<Plan>,
    /// Seed for the deterministic synthetic message tensors.
    pub seed: u64,
    /// Shared wire options: `profile` is the model the `SimNet`
    /// reference replay simulates, `recv_timeout_s` bounds every real
    /// mailbox wait. The backend is a harness *argument* (reference vs.
    /// loopback vs. rank entry points), so `wire.backend` is unused
    /// here.
    pub wire: WireOpts,
    /// Schedule repetitions: microbatch ids repeat across steps, so
    /// AQ-SGD bootstraps once and then ships deltas.
    pub steps: usize,
    /// Data-parallel replicas (`--dp.replicas`). With `dp > 1` every
    /// rank doubles as one replica of the whole pipeline (so `dp` must
    /// equal `stages`) and each schedule round is followed by a
    /// compressed ring-allreduce of a synthetic per-replica gradient —
    /// tag-5 frames on the same mailboxes, in a disjoint key space
    /// (see [`run_allreduce`]). 1 is today's behavior, bit-identical.
    pub dp: usize,
}

impl WorkerOpts {
    /// Virtual stages per rank (1 for the flat schedules).
    pub fn chunks(&self) -> usize {
        self.schedule.chunks()
    }

    /// Physical wire links of this run's topology.
    pub fn wire_links(&self) -> usize {
        pipeline::num_wire_links(self.stages, self.chunks())
    }

    /// The plan every channel spec is keyed through: the loaded plan
    /// file, or the uniform plan of the CLI spec. Its digest is what
    /// the rendezvous handshake negotiates — so two ranks launched with
    /// different `--compression` flags (or different plan files) fail
    /// with a typed `PlanMismatch` instead of decoding garbage.
    pub fn effective_plan(&self) -> Result<Plan> {
        let v = self.chunks();
        let plan = match &self.plan {
            Some(p) => {
                // byte parity doesn't model queue windows, so only the
                // shape is validated here (cap passes trivially)
                p.validate_for(self.stages, v, usize::MAX)?;
                p.clone()
            }
            None => Plan::uniform(
                self.spec,
                self.stages,
                v,
                crate::netsim::DEFAULT_QUEUE_CAPACITY,
            ),
        };
        Ok(plan)
    }
}

/// What one endpoint saw on one `(link, dir)` mailbox.
#[derive(Clone, Debug, PartialEq)]
pub struct MailboxLog {
    /// Physical wire link of this mailbox.
    pub link: usize,
    /// Message direction of this mailbox.
    pub dir: Dir,
    /// `(key, bytes, payload digest)` in delivery order.
    pub recv: Vec<(u64, usize, u64)>,
    /// Messages this endpoint sent on the mailbox's channel.
    pub sent_msgs: u64,
    /// Bytes this endpoint sent on the mailbox's channel.
    pub sent_bytes: u64,
}

/// The deterministic outcome of one worker (or reference) run.
#[derive(Clone, Debug)]
pub struct WorkerSummary {
    /// Transport backend name (`sim`, `tcp`, `uds`).
    pub backend: String,
    /// `None` for the single-process reference run (all stages).
    pub rank: Option<usize>,
    /// One log per `(link, dir)`, index `link * 2 + dir`.
    pub boxes: Vec<MailboxLog>,
    /// Measured wall-clock tx time (0 for the reference).
    pub wire_elapsed_s: f64,
}

/// Deterministic synthetic tensor for the message `(link, dir, chunk,
/// mb)` — stable across steps, the fixed-batch analogue of revisiting
/// the same training samples. `chunk` distinguishes boundaries sharing
/// a ring link (always 0 on a chain, keeping v=1 tensors identical to
/// the pre-interleaving ones).
fn gen_tensor(opts: &WorkerOpts, link: usize, dir: Dir, chunk: usize, mb: usize) -> Vec<f32> {
    let tag = ((link as u64) << 40)
        | ((dir.index() as u64) << 32)
        | ((chunk as u64) << 24)
        | mb as u64;
    let mut rng = Rng::with_stream(opts.seed, tag);
    let mut v = vec![0.0f32; opts.link_elems];
    rng.fill_normal(&mut v, 0.0, 1.0);
    v
}

/// Compress + encode the message for `(link, dir, chunk, mb)` with the
/// actual wire codecs (what the trainer's links put on a real socket),
/// under the channel's own `spec` (plans assign these per boundary).
/// Feedback modes advance `state` — the sender half of this channel.
fn encode_message(
    opts: &WorkerOpts,
    spec: &Spec,
    state: &mut FeedbackState,
    link: usize,
    dir: Dir,
    chunk: usize,
    mb: usize,
) -> Result<Vec<u8>> {
    let x = gen_tensor(opts, link, dir, chunk, mb);
    match spec.method {
        Method::None => Ok(wire::encode_raw(&x)),
        Method::Quant { fw_bits, bw_bits } => {
            let bits = if dir == Dir::Fwd { fw_bits } else { bw_bits };
            Ok(wire::encode_quant(&x, bits))
        }
        Method::TopK { frac, shared_idx, feedback } => {
            if shared_idx {
                bail!(
                    "worker does not model shared-index masks (got '{}')",
                    spec.label()
                );
            }
            match channel_feedback(feedback, dir) {
                Feedback::None => {
                    let (dense, _) = ops::topk(&x, frac);
                    let k = dense.iter().filter(|&&v| v != 0.0).count();
                    Ok(wire::encode_sparse(&dense, k))
                }
                Feedback::Ef => {
                    let buf = state.global_mut(x.len()).data().to_vec();
                    let (c, e) = ops::ef_combine(&x, &buf, frac);
                    let k = c.iter().filter(|&&v| v != 0.0).count();
                    state.set_global(crate::tensor::Tensor::from_vec(e));
                    Ok(wire::encode_sparse(&c, k))
                }
                Feedback::EfMixed => {
                    let buf = state.global_mut(x.len()).data().to_vec();
                    let (c, e) = ops::ef_mixed(&x, &buf, frac);
                    let k = c.iter().filter(|&&v| v != 0.0).count();
                    state.set_global(crate::tensor::Tensor::from_vec(e));
                    Ok(wire::encode_sparse(&c, k))
                }
                fb => Ok(state.sender_encode(fb, mb as u64, &x, frac)?.0),
            }
        }
    }
}

/// The feedback mode active on one channel direction (AQ-SGD is
/// activations-only, so its backward channels run plain TopK).
fn channel_feedback(fb: Feedback, dir: Dir) -> Feedback {
    if dir == Dir::Bwd && !applies_to_bwd(fb) {
        Feedback::None
    } else {
        fb
    }
}

/// The wire hop carrying data-parallel replica `r`'s allreduce sends.
/// Replica `r` is mapped onto rank `r` (so `dp == stages`): chain hops
/// ride the forward mailboxes of the existing physical links; the wrap
/// hop (last replica -> replica 0) rides the ring's wrap link when the
/// schedule interleaves (`v > 1`), or the backward mailbox of link 0 on
/// a 2-rank chain. Longer flat chains have no wire for the wrap and are
/// rejected with a typed error. In every case the rank that *receives*
/// the hop's frames is the rank hosting the destination replica, so the
/// one-consumer-per-mailbox discipline the threaded and multi-process
/// paths rely on is preserved.
fn allreduce_hop(stages: usize, v: usize, r: usize) -> Result<(usize, Dir)> {
    if r < stages - 1 {
        return Ok((r, Dir::Fwd));
    }
    if v > 1 {
        return Ok((stages - 1, Dir::Fwd));
    }
    if stages == 2 {
        return Ok((0, Dir::Bwd));
    }
    bail!(
        "dp={stages} allreduce on a {stages}-rank flat chain has no wire for the wrap hop \
         (replica {r} -> 0): use an interleaved schedule (ring topology) or 2 stages"
    )
}

/// Build the per-replica allreduce rings this endpoint drives (`None`
/// for replicas other processes own). Empty when `dp <= 1`. Validates
/// the replica->rank mapping and the hop topology up front, before any
/// schedule traffic.
fn build_allreduce_rings(
    opts: &WorkerOpts,
    mine: &dyn Fn(usize) -> bool,
) -> Result<Vec<Option<ReplicaRing>>> {
    if opts.dp <= 1 {
        return Ok(Vec::new());
    }
    let dp = opts.dp;
    if dp != opts.stages {
        bail!(
            "--dp.replicas={dp} wants one replica per rank, got {} stages: the worker \
             harness carries replica r's ring hop on rank r's wire",
            opts.stages
        );
    }
    let v = opts.chunks();
    for r in 0..dp {
        allreduce_hop(opts.stages, v, r)?;
    }
    (0..dp)
        .map(|r| {
            if mine(r) {
                ReplicaRing::new(dp, r, opts.link_elems, opts.spec).map(Some)
            } else {
                Ok(None)
            }
        })
        .collect()
}

/// One compressed ring-allreduce round of a hybrid-DP run (`dp > 1`):
/// every replica loads a fresh synthetic gradient (PCG32 stream keyed
/// by `(seed, replica, round)` — disjoint from the schedule-tensor
/// streams), then walks the `2*(dp-1)` reduce-scatter + all-gather
/// steps, shipping [`ReplicaRing`] tag-5 frames over the hop mailboxes
/// in a high-bit transport key space that cannot collide with schedule
/// keys. Rings persist across rounds, so EF21 segment generations
/// genuinely advance. Frames and delivery order land in the same
/// [`MailboxLog`]s as schedule traffic, which is what puts the
/// allreduce path under the [`check`] sim/real parity contract. In
/// single-process runs the finished means are asserted bit-identical
/// across replicas (the ring's loss-consistent broadcast contract).
fn run_allreduce_round(
    opts: &WorkerOpts,
    net: &mut dyn Transport,
    mine: &dyn Fn(usize) -> bool,
    rings: &mut [Option<ReplicaRing>],
    round: usize,
    boxes: &mut [MailboxLog],
    sent_frames: &mut [HashMap<u64, Vec<u8>>],
) -> Result<()> {
    let dp = opts.dp;
    let v = opts.chunks();
    // attribute ring traffic to its own counter channel, not a boundary
    crate::telemetry::set_channel_hint(crate::telemetry::CHANNEL_ALLREDUCE);
    for (r, ring) in rings.iter_mut().enumerate() {
        let Some(ring) = ring else { continue };
        let tag = (1u64 << 62) | ((r as u64) << 32) | round as u64;
        let mut g = vec![0.0f32; opts.link_elems];
        Rng::with_stream(opts.seed, tag).fill_normal(&mut g, 0.0, 1.0);
        ring.load(&g)?;
    }
    let num_steps = 2 * (dp - 1);
    for step in 0..num_steps {
        let key = (1u64 << 63) | (round * num_steps + step) as u64;
        // ring discipline: every local replica sends its hop frame
        // before any blocks on its upstream recv — deadlock-free on
        // real sockets, and the all-send-then-all-deliver order gives
        // the SimNet reference run_in_memory's barrier semantics
        for r in 0..dp {
            if !mine(r) {
                continue;
            }
            let ring = rings[r].as_mut().expect("mine(r) built a ring");
            let buf = ring.make_frame(step)?;
            let (link, dir) = allreduce_hop(opts.stages, v, r)?;
            let mbx = link * 2 + dir.index();
            if !net.wants_payload() {
                sent_frames[mbx].insert(key, buf.clone());
            }
            let seg = ring.seg_len(ring.send_seg(step));
            let raw = wire::allreduce_wire_bytes(wire::raw_wire_bytes(seg));
            net.send(link, dir, key, Payload::Bytes(&buf), raw, 0.0)
                .with_context(|| format!("allreduce send replica {r} step {step}"))?;
            boxes[mbx].sent_msgs += 1;
            boxes[mbx].sent_bytes += buf.len() as u64;
        }
        for r in 0..dp {
            if !mine(r) {
                continue;
            }
            let upstream = (r + dp - 1) % dp;
            let (link, dir) = allreduce_hop(opts.stages, v, upstream)?;
            let mbx = link * 2 + dir.index();
            let t0 = crate::telemetry::spans_on().then(|| net.clock(r));
            let frame = net
                .recv(link, dir, key)
                .with_context(|| format!("allreduce recv replica {r} step {step}"))?;
            let local = sent_frames[mbx].get(&key);
            let buf: &[u8] = match (&frame.payload, local) {
                (Some(p), _) => p,
                (None, Some(l)) => l,
                (None, None) => bail!("sim reference: allreduce recv before send"),
            };
            let ring = rings[r].as_mut().expect("mine(r) built a ring");
            ring.apply_frame(step, buf)
                .with_context(|| format!("allreduce apply replica {r} step {step}"))?;
            if let Some(t0) = t0 {
                crate::telemetry::span_at(r as u32, "hop", "allreduce", t0, net.clock(r), key);
            }
            boxes[mbx].recv.push((key, frame.bytes, fnv1a(buf)));
        }
    }
    let mut means: Vec<(usize, Vec<f32>)> = Vec::new();
    for (r, ring) in rings.iter_mut().enumerate() {
        let Some(ring) = ring else { continue };
        means.push((r, ring.finish()?));
    }
    if means.len() == dp {
        let (r0, first) = &means[0];
        debug_assert_eq!(*r0, 0);
        for (r, m) in &means[1..] {
            let same = m.len() == first.len()
                && m.iter().zip(first).all(|(a, b)| a.to_bits() == b.to_bits());
            if !same {
                bail!("allreduce round {round}: replica {r} mean diverged from replica 0");
            }
        }
    }
    Ok(())
}

/// Walk the training schedule (repeated `steps` times): the ops come
/// from [`pipeline::ops_for`] and the microbatch count from `opts.mb`.
fn run_stages(
    opts: &WorkerOpts,
    plan: &Plan,
    net: &mut dyn Transport,
    mine: &dyn Fn(usize) -> bool,
) -> Result<Vec<MailboxLog>> {
    let ops = pipeline::ops_for(opts.schedule, opts.stages, opts.mb)?;
    run_ops(opts, plan, net, mine, &ops, opts.mb)
}

/// Walk an explicit op list (repeated `steps` times), executing
/// send/recv for every rank `mine` accepts, and log what each mailbox
/// saw. With `mine = |_| true` and a `SimNet` (or loopback real
/// transport) this is the single-process replay; with
/// `mine = |r| r == rank` over an endpoint transport it is one rank of
/// a multi-process run. `mb_count` is the number of distinct microbatch
/// ids the ops use (`opts.mb` for training schedules, the admitted
/// batch count for serving) — it scales the per-channel transport keys.
///
/// Protocol state (feedback sender halves + receiver mirrors) is kept
/// **per channel**: one slot per `(link, dir, chunk)`, where `chunk`
/// is the boundary's index among the boundaries sharing that physical
/// link (`boundary / stages`) — always 0 on a chain, so flat runs are
/// byte-identical to the pre-interleaving protocol.
pub(crate) fn run_ops(
    opts: &WorkerOpts,
    plan: &Plan,
    net: &mut dyn Transport,
    mine: &dyn Fn(usize) -> bool,
    ops: &[pipeline::Op],
    mb_count: usize,
) -> Result<Vec<MailboxLog>> {
    let stages = opts.stages;
    let v = opts.chunks();
    let links = opts.wire_links();
    let mut boxes: Vec<MailboxLog> = (0..links)
        .flat_map(|link| {
            [Dir::Fwd, Dir::Bwd].into_iter().map(move |dir| MailboxLog {
                link,
                dir,
                recv: Vec::new(),
                sent_msgs: 0,
                sent_bytes: 0,
            })
        })
        .collect();
    // per-channel protocol state: sender half for channels this endpoint
    // produces, receiver mirror for channels it consumes — one slot per
    // (link, dir, chunk)
    let slots = links * 2 * v;
    let mut senders: Vec<FeedbackState> = (0..slots).map(|_| FeedbackState::new()).collect();
    let mut mirrors: Vec<FeedbackState> = (0..slots).map(|_| FeedbackState::new()).collect();
    // frames recorded at send time, for backends whose delivered frames
    // carry no payload (the SimNet reference decodes its local copy)
    let mut sent_frames: Vec<HashMap<u64, Vec<u8>>> =
        (0..links * 2).map(|_| Default::default()).collect();

    // one boundary -> one channel: its physical link, its chunk index
    // among the boundaries sharing that link, its unique transport key
    // (stable AQ-SGD sample keys ride *inside* the delta frames), the
    // mailbox index, and the protocol-state slot. Sender and receiver
    // must derive these identically, so there is exactly one place.
    let channel = |boundary: usize, dir: Dir, step: usize, mb: usize| {
        let link = pipeline::boundary_link(boundary, stages)
            .expect("multi-rank runs have wire links");
        let chunk = boundary / stages;
        let key = ((step * v + chunk) * mb_count + mb) as u64;
        let mbx = link * 2 + dir.index();
        (link, chunk, key, mbx, mbx * v + chunk)
    };
    // hybrid-DP: per-replica allreduce rings, persistent across rounds
    // (empty when dp == 1 — nothing about the plain run changes)
    let mut rings = build_allreduce_rings(opts, mine)?;
    for step in 0..opts.steps.max(1) {
        for op in ops {
            let (rank, mb) = (op.rank(), op.mb());
            let dir = if op.is_fwd() { Dir::Fwd } else { Dir::Bwd };
            if !mine(rank) {
                continue;
            }
            let op_t0 = crate::telemetry::spans_on().then(|| net.clock(rank));
            // receive this op's input frame (if its boundary has a wire)
            if let Some(boundary) = pipeline::input_boundary(op, stages, v) {
                let (link, chunk, key, mbx, slot) = channel(boundary, dir, step, mb);
                crate::telemetry::set_channel_hint(boundary as u32);
                let frame = net
                    .recv(link, dir, key)
                    .with_context(|| format!("rank recv link {link} {dir} chunk {chunk} mb {mb}"))?;
                let local = sent_frames[mbx].get(&key);
                let buf: &[u8] = match (&frame.payload, local) {
                    (Some(p), _) => p,
                    (None, Some(l)) => l,
                    (None, None) => bail!("sim reference: recv before send"),
                };
                // receiver half: delta frames must advance the mirror
                // (generation + digest verified) before the payload
                // counts as delivered — no silent state skew. The mode
                // comes from this *channel's* planned spec.
                if wire::is_delta_frame(buf) {
                    let fb = match plan.spec_for(boundary, dir).method {
                        Method::TopK { feedback, .. } => channel_feedback(feedback, dir),
                        _ => Feedback::None,
                    };
                    let df = wire::decode_delta(buf)
                        .with_context(|| format!("link {link} {dir} mb {mb}"))?;
                    mirrors[slot]
                        .apply_frame(fb, &df, opts.link_elems)
                        .with_context(|| format!("link {link} {dir} mb {mb}: mirror"))?;
                }
                boxes[mbx].recv.push((key, frame.bytes, fnv1a(buf)));
            }
            // send this op's output frame (if its boundary has a wire)
            if let Some(boundary) = pipeline::output_boundary(op, stages, v) {
                let (link, chunk, key, mbx, slot) = channel(boundary, dir, step, mb);
                let spec = plan.spec_for(boundary, dir);
                crate::telemetry::set_channel_hint(boundary as u32);
                let buf = encode_message(opts, spec, &mut senders[slot], link, dir, chunk, mb)?;
                if !net.wants_payload() {
                    sent_frames[mbx].insert(key, buf.clone());
                }
                let raw = wire::raw_wire_bytes(opts.link_elems);
                net.send(link, dir, key, Payload::Bytes(&buf), raw, 0.0)
                    .with_context(|| format!("rank send link {link} {dir} chunk {chunk} mb {mb}"))?;
                boxes[mbx].sent_msgs += 1;
                boxes[mbx].sent_bytes += buf.len() as u64;
            }
            if let Some(t0) = op_t0 {
                let name = if op.is_fwd() { "fwd" } else { "bwd" };
                crate::telemetry::span_at(rank as u32, name, "op", t0, net.clock(rank), mb as u64);
            }
        }
        if !rings.is_empty() {
            run_allreduce_round(opts, net, mine, &mut rings, step, &mut boxes, &mut sent_frames)?;
        }
    }
    Ok(boxes)
}

/// Single-process reference: the whole schedule over `SimNet`.
pub fn run_reference(opts: &WorkerOpts) -> Result<WorkerSummary> {
    crate::telemetry::set_virtual_clock(true);
    let plan = opts.effective_plan()?;
    let mut net = SimNet::new(opts.wire_links(), opts.wire.model()?);
    let boxes = run_stages(opts, &plan, &mut net, &|_| true)?;
    Ok(WorkerSummary { backend: "sim".into(), rank: None, boxes, wire_elapsed_s: 0.0 })
}

/// Single-process run over a real loopback transport (both ends of
/// every link in this process) — the in-test analogue of the
/// multi-process path.
pub fn run_loopback(opts: &WorkerOpts, backend: Backend) -> Result<WorkerSummary> {
    crate::telemetry::set_virtual_clock(false);
    let plan = opts.effective_plan()?;
    let links = opts.wire_links();
    let model = opts.wire.model()?;
    let timeout = std::time::Duration::from_secs_f64(opts.wire.recv_timeout_s);
    // udp runs through its reliability layer; its fault-injection knobs
    // come from the MPCOMP_UDP_* environment so WorkerOpts stays stable
    let (boxes, elapsed) = if backend == Backend::Udp {
        let faults = UdpFaults::from_env();
        let mut net = UdpTransport::loopback(links, model, timeout, &faults)?;
        let boxes = run_stages(opts, &plan, &mut net, &|_| true)?;
        let elapsed = net.wire_elapsed_s();
        net.shutdown()?;
        (boxes, elapsed)
    } else {
        let mut net = RealTransport::loopback(links, backend, model, timeout)?;
        let boxes = run_stages(opts, &plan, &mut net, &|_| true)?;
        let elapsed = net.wire_elapsed_s();
        net.shutdown()?;
        (boxes, elapsed)
    };
    Ok(WorkerSummary {
        backend: backend.name().into(),
        rank: None,
        boxes,
        wire_elapsed_s: elapsed,
    })
}

/// One rank of a multi-process run: rendezvous with the neighbor
/// processes (a chain for flat schedules, a ring once chunks
/// interleave), execute this rank's ops, shut down gracefully.
pub fn run_rank(
    opts: &WorkerOpts,
    rank: usize,
    backend: Backend,
    rendezvous_addr: &str,
) -> Result<WorkerSummary> {
    if rank >= opts.stages {
        bail!("rank {rank} out of range for {} stages", opts.stages);
    }
    crate::telemetry::set_virtual_clock(false);
    let plan = opts.effective_plan()?;
    let model = opts.wire.model()?;
    let mut rv = Rendezvous::parse(backend, opts.stages, rendezvous_addr)?;
    rv.recv_timeout = std::time::Duration::from_secs_f64(opts.wire.recv_timeout_s);
    rv.ring = opts.chunks() > 1 && opts.stages > 1;
    // the handshake negotiates the plan digest: a peer that loaded a
    // different plan (or a different --compression) is refused with a
    // typed PlanMismatch before any frame or mirror update happens —
    // and the digest comes from the same resolved plan the stage loop
    // encodes with
    rv.plan_digest = plan.digest();
    let (boxes, elapsed) = if backend == Backend::Udp {
        let mut net = UdpTransport::endpoint(&rv, rank, model, &UdpFaults::from_env())?;
        let boxes = run_stages(opts, &plan, &mut net, &|s| s == rank)?;
        let elapsed = net.wire_elapsed_s();
        net.shutdown()?;
        (boxes, elapsed)
    } else {
        let mut net = RealTransport::endpoint(&rv, rank, model)?;
        let boxes = run_stages(opts, &plan, &mut net, &|s| s == rank)?;
        let elapsed = net.wire_elapsed_s();
        net.shutdown()?;
        (boxes, elapsed)
    };
    Ok(WorkerSummary {
        backend: backend.name().into(),
        rank: Some(rank),
        boxes,
        wire_elapsed_s: elapsed,
    })
}

/// The forward-only op list of a serve-mode parity run: the open-loop
/// arrival stream and the admission layer are both deterministic
/// functions of `(seed, knobs)`, so every process derives the identical
/// microbatch composition locally — no admission traffic crosses the
/// wire, and the transport keys (scaled by the admitted batch count)
/// agree across ranks by construction.
fn serve_schedule(opts: &WorkerOpts, knobs: &ServeKnobs) -> (Vec<pipeline::Op>, usize) {
    let arr = arrivals::poisson(opts.seed, knobs.rate_rps, knobs.requests);
    let batches = serve::admit(&arr, knobs.max_batch, knobs.deadline_s);
    (serve::serve_ops(opts.stages, opts.chunks(), batches.len()), batches.len())
}

/// Serve-mode analogue of [`run_reference`]: the whole forward-only
/// admission schedule replayed over `SimNet` in one process.
pub fn run_serve_reference(opts: &WorkerOpts, knobs: &ServeKnobs) -> Result<WorkerSummary> {
    crate::telemetry::set_virtual_clock(true);
    let plan = opts.effective_plan()?;
    let (ops, nb) = serve_schedule(opts, knobs);
    let mut net = SimNet::new(opts.wire_links(), opts.wire.model()?);
    let boxes = run_ops(opts, &plan, &mut net, &|_| true, &ops, nb)?;
    Ok(WorkerSummary { backend: "sim".into(), rank: None, boxes, wire_elapsed_s: 0.0 })
}

/// Serve-mode analogue of [`run_loopback`]: both ends of every link in
/// this process over a real socket transport.
pub fn run_serve_loopback(
    opts: &WorkerOpts,
    knobs: &ServeKnobs,
    backend: Backend,
) -> Result<WorkerSummary> {
    crate::telemetry::set_virtual_clock(false);
    let plan = opts.effective_plan()?;
    let (ops, nb) = serve_schedule(opts, knobs);
    let links = opts.wire_links();
    let model = opts.wire.model()?;
    let timeout = std::time::Duration::from_secs_f64(opts.wire.recv_timeout_s);
    let (boxes, elapsed) = if backend == Backend::Udp {
        let faults = UdpFaults::from_env();
        let mut net = UdpTransport::loopback(links, model, timeout, &faults)?;
        let boxes = run_ops(opts, &plan, &mut net, &|_| true, &ops, nb)?;
        let elapsed = net.wire_elapsed_s();
        net.shutdown()?;
        (boxes, elapsed)
    } else {
        let mut net = RealTransport::loopback(links, backend, model, timeout)?;
        let boxes = run_ops(opts, &plan, &mut net, &|_| true, &ops, nb)?;
        let elapsed = net.wire_elapsed_s();
        net.shutdown()?;
        (boxes, elapsed)
    };
    Ok(WorkerSummary {
        backend: backend.name().into(),
        rank: None,
        boxes,
        wire_elapsed_s: elapsed,
    })
}

/// Serve-mode analogue of [`run_rank`]: one rank of a multi-process
/// serving run. Admission is recomputed locally (see
/// [`serve_schedule`]) and the rendezvous handshake still negotiates
/// the plan digest, so mismatched plans are refused before any frame.
pub fn run_serve_rank(
    opts: &WorkerOpts,
    knobs: &ServeKnobs,
    rank: usize,
    backend: Backend,
    rendezvous_addr: &str,
) -> Result<WorkerSummary> {
    if rank >= opts.stages {
        bail!("rank {rank} out of range for {} stages", opts.stages);
    }
    crate::telemetry::set_virtual_clock(false);
    let plan = opts.effective_plan()?;
    let (ops, nb) = serve_schedule(opts, knobs);
    let model = opts.wire.model()?;
    let mut rv = Rendezvous::parse(backend, opts.stages, rendezvous_addr)?;
    rv.recv_timeout = std::time::Duration::from_secs_f64(opts.wire.recv_timeout_s);
    rv.ring = opts.chunks() > 1 && opts.stages > 1;
    rv.plan_digest = plan.digest();
    let (boxes, elapsed) = if backend == Backend::Udp {
        let mut net = UdpTransport::endpoint(&rv, rank, model, &UdpFaults::from_env())?;
        let boxes = run_ops(opts, &plan, &mut net, &|s| s == rank, &ops, nb)?;
        let elapsed = net.wire_elapsed_s();
        net.shutdown()?;
        (boxes, elapsed)
    } else {
        let mut net = RealTransport::endpoint(&rv, rank, model)?;
        let boxes = run_ops(opts, &plan, &mut net, &|s| s == rank, &ops, nb)?;
        let elapsed = net.wire_elapsed_s();
        net.shutdown()?;
        (boxes, elapsed)
    };
    Ok(WorkerSummary {
        backend: backend.name().into(),
        rank: Some(rank),
        boxes,
        wire_elapsed_s: elapsed,
    })
}

/// Assert worker summaries are bit-identical to the reference run:
/// every mailbox a worker received must match the reference's ordered
/// `(key, bytes, digest)` log exactly, every sender must have charged
/// the same bytes, and together the workers must cover every message
/// the reference saw.
pub fn check(reference: &WorkerSummary, workers: &[WorkerSummary]) -> Result<()> {
    for w in workers {
        if w.boxes.len() != reference.boxes.len() {
            bail!(
                "worker {:?}: {} mailboxes, reference has {}",
                w.rank,
                w.boxes.len(),
                reference.boxes.len()
            );
        }
        for (wb, rb) in w.boxes.iter().zip(&reference.boxes) {
            if !wb.recv.is_empty() && wb.recv != rb.recv {
                bail!(
                    "worker {:?} link {} {}: delivery log diverged\n  got:  {:?}\n  want: {:?}",
                    w.rank,
                    wb.link,
                    wb.dir,
                    wb.recv,
                    rb.recv
                );
            }
            if wb.sent_msgs > 0
                && (wb.sent_msgs != rb.sent_msgs || wb.sent_bytes != rb.sent_bytes)
            {
                bail!(
                    "worker {:?} link {} {}: sent {} msgs / {} B, reference {} msgs / {} B",
                    w.rank,
                    wb.link,
                    wb.dir,
                    wb.sent_msgs,
                    wb.sent_bytes,
                    rb.sent_msgs,
                    rb.sent_bytes
                );
            }
        }
    }
    for (i, rb) in reference.boxes.iter().enumerate() {
        let got: usize = workers.iter().map(|w| w.boxes[i].recv.len()).sum();
        if got != rb.recv.len() {
            bail!(
                "link {} {}: workers received {got} messages, reference saw {}",
                rb.link,
                rb.dir,
                rb.recv.len()
            );
        }
    }
    Ok(())
}

/// Byte-accounting check for the error-feedback protocol: summed per
/// mailbox across `candidates` (e.g. the ranks of an EF run), sent
/// bytes must never exceed the `baseline` run's (same schedule,
/// feedback=none), and the grand total must be **strictly** below —
/// the paper's communication-saving claim, enforced on measured
/// traffic. Returns `(baseline_total, candidate_total)`.
pub fn compare_bytes(
    baseline: &WorkerSummary,
    candidates: &[WorkerSummary],
) -> Result<(u64, u64)> {
    for c in candidates {
        if c.boxes.len() != baseline.boxes.len() {
            bail!(
                "candidate {:?}: {} mailboxes, baseline has {}",
                c.rank,
                c.boxes.len(),
                baseline.boxes.len()
            );
        }
    }
    let mut base_total = 0u64;
    let mut cand_total = 0u64;
    for (i, rb) in baseline.boxes.iter().enumerate() {
        let cand: u64 = candidates.iter().map(|c| c.boxes[i].sent_bytes).sum();
        if cand > rb.sent_bytes {
            bail!(
                "link {} {}: error feedback sent {cand} B, exceeding the {} B baseline",
                rb.link,
                rb.dir,
                rb.sent_bytes
            );
        }
        base_total += rb.sent_bytes;
        cand_total += cand;
    }
    if cand_total >= base_total {
        bail!("error feedback sent {cand_total} B, not below the {base_total} B baseline");
    }
    Ok((base_total, cand_total))
}

// ---------------------------------------------------------------------------
// summary (de)serialization — the CI job diffs rank files via `--check`
// ---------------------------------------------------------------------------

impl WorkerSummary {
    /// Serialize for the CI parity files (`--out`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("backend", Json::Str(self.backend.clone()));
        o.set("rank", self.rank.map_or(Json::Null, |r| Json::Num(r as f64)));
        o.set("wire_elapsed_s", Json::Num(self.wire_elapsed_s));
        let boxes: Vec<Json> = self
            .boxes
            .iter()
            .map(|b| {
                let mut jb = Json::object();
                jb.set("link", Json::Num(b.link as f64));
                jb.set("dir", Json::Str(b.dir.name().into()));
                jb.set("sent_msgs", Json::Num(b.sent_msgs as f64));
                jb.set("sent_bytes", Json::Num(b.sent_bytes as f64));
                let recv: Vec<Json> = b
                    .recv
                    .iter()
                    .map(|(key, bytes, digest)| {
                        let mut jr = Json::object();
                        jr.set("key", Json::Num(*key as f64));
                        jr.set("bytes", Json::Num(*bytes as f64));
                        // digests exceed f64's integer range: hex string
                        jr.set("digest", Json::Str(format!("{digest:016x}")));
                        jr
                    })
                    .collect();
                jb.set("recv", Json::Arr(recv));
                jb
            })
            .collect();
        o.set("boxes", Json::Arr(boxes));
        o
    }

    /// Inverse of [`Self::to_json`].
    pub fn from_json(j: &Json) -> Result<WorkerSummary> {
        let rank = match j.get("rank")? {
            Json::Null => None,
            v => Some(v.usize()?),
        };
        let mut boxes = Vec::new();
        for jb in j.get("boxes")?.arr()? {
            let mut recv = Vec::new();
            for jr in jb.get("recv")?.arr()? {
                let key = jr.get("key")?.num()? as u64;
                let bytes = jr.get("bytes")?.usize()?;
                let digest = u64::from_str_radix(jr.get("digest")?.str()?, 16)
                    .context("bad digest hex")?;
                recv.push((key, bytes, digest));
            }
            boxes.push(MailboxLog {
                link: jb.get("link")?.usize()?,
                dir: Dir::parse(jb.get("dir")?.str()?)?,
                recv,
                sent_msgs: jb.get("sent_msgs")?.num()? as u64,
                sent_bytes: jb.get("sent_bytes")?.num()? as u64,
            });
        }
        Ok(WorkerSummary {
            backend: j.get("backend")?.str()?.to_string(),
            rank,
            boxes,
            wire_elapsed_s: j.get("wire_elapsed_s")?.num()?,
        })
    }

    /// Write the JSON summary to `path`.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {path}"))
    }

    /// Read a JSON summary produced by [`Self::save`].
    pub fn load(path: &str) -> Result<WorkerSummary> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        WorkerSummary::from_json(&Json::parse(&text)?)
    }

    /// Total messages this endpoint received.
    pub fn received(&self) -> usize {
        self.boxes.iter().map(|b| b.recv.len()).sum()
    }

    /// Total bytes this endpoint sent across all mailboxes.
    pub fn sent_bytes(&self) -> u64 {
        self.boxes.iter().map(|b| b.sent_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(stages: usize, mb: usize, mode: &str) -> WorkerOpts {
        WorkerOpts {
            stages,
            mb,
            link_elems: 64,
            schedule: Schedule::GPipe,
            spec: Spec::parse(mode).unwrap(),
            plan: None,
            seed: 11,
            wire: WireOpts {
                profile: "datacenter".into(),
                recv_timeout_s: 5.0,
                ..WireOpts::default()
            },
            steps: 1,
            dp: 1,
        }
    }

    #[test]
    fn reference_is_deterministic_and_self_consistent() {
        let o = opts(3, 4, "topk:10");
        let a = run_reference(&o).unwrap();
        let b = run_reference(&o).unwrap();
        assert_eq!(a.boxes, b.boxes);
        // 2 links x 2 dirs, every mailbox saw all 4 microbatches
        assert_eq!(a.boxes.len(), 4);
        for mbx in &a.boxes {
            assert_eq!(mbx.recv.len(), 4, "link {} {}", mbx.link, mbx.dir);
            assert_eq!(mbx.sent_msgs, 4);
        }
        check(&a, std::slice::from_ref(&b)).unwrap();
    }

    #[test]
    fn reference_changes_with_seed_and_spec() {
        let a = run_reference(&opts(2, 2, "topk:10")).unwrap();
        let mut o = opts(2, 2, "topk:10");
        o.seed = 12;
        let b = run_reference(&o).unwrap();
        assert_ne!(a.boxes, b.boxes, "digests must depend on the seed");
        let c = run_reference(&opts(2, 2, "none")).unwrap();
        assert_ne!(
            a.boxes[0].sent_bytes, c.boxes[0].sent_bytes,
            "topk must ship fewer bytes than raw"
        );
    }

    #[test]
    fn shared_index_specs_are_rejected() {
        let o = opts(2, 2, "topk:10:shared");
        assert!(run_reference(&o).is_err());
    }

    /// A heterogeneous plan keys every channel's codec and feedback
    /// state by boundary: the reference replay is deterministic, the
    /// per-mailbox frames differ from any uniform run, and byte counts
    /// match each channel's own spec.
    #[test]
    fn plan_keys_specs_by_boundary_channel() {
        use crate::planner::{BoundaryPlan, Plan};
        let mut o = opts(2, 4, "topk:10");
        o.schedule = Schedule::Interleaved { v: 2 };
        o.steps = 2;
        o.link_elems = 512;
        let plan = Plan {
            n_ranks: 2,
            v: 2,
            queue_cap: 4,
            boundaries: vec![
                BoundaryPlan {
                    fwd: Spec::parse("topk:10").unwrap(),
                    bwd: Spec::parse("quant:fw8-bw8").unwrap(),
                },
                BoundaryPlan {
                    fwd: Spec::parse("ef21+topk:10").unwrap(),
                    bwd: Spec::parse("topk:30").unwrap(),
                },
                BoundaryPlan {
                    fwd: Spec::parse("quant:fw4-bw8").unwrap(),
                    bwd: Spec::none(),
                },
            ],
        };
        o.plan = Some(plan.clone());
        let a = run_reference(&o).unwrap();
        let b = run_reference(&o).unwrap();
        assert_eq!(a.boxes, b.boxes, "planned reference must be deterministic");
        check(&a, std::slice::from_ref(&b)).unwrap();
        // boundary 2 bwd is uncompressed: that channel's frames are the
        // raw size; boundary 0 bwd is 8-bit quant (smaller); both ride
        // link 0 bwd, distinguished by chunk-qualified keys
        let raw = wire::raw_wire_bytes(o.link_elems);
        let quant = wire::quant_wire_bytes(o.link_elems, 8);
        let bwd0 = &a.boxes[1]; // link 0, bwd carries boundaries 0 and 2
        let sizes: std::collections::HashSet<usize> =
            bwd0.recv.iter().map(|r| r.1).collect();
        assert!(sizes.contains(&raw), "uncompressed boundary missing: {sizes:?}");
        assert!(sizes.contains(&quant), "quantized boundary missing: {sizes:?}");
        // and the run differs from the uniform spec it would fall back to
        let mut uniform = o.clone();
        uniform.plan = None;
        let u = run_reference(&uniform).unwrap();
        assert_ne!(a.boxes, u.boxes);
        // a plan whose shape doesn't match the run is a typed error
        let mut wrong = o.clone();
        wrong.stages = 3;
        assert!(run_reference(&wrong).is_err());
    }

    #[test]
    fn every_feedback_mode_runs_and_is_deterministic() {
        for mode in ["ef+topk:10", "efmixed+topk:10", "ef21+topk:10", "aqsgd+topk:30"] {
            let mut o = opts(2, 3, mode);
            o.steps = 2;
            let a = run_reference(&o).unwrap_or_else(|e| panic!("{mode}: {e}"));
            let b = run_reference(&o).unwrap();
            assert_eq!(a.boxes, b.boxes, "{mode}: not deterministic");
            for mbx in &a.boxes {
                assert_eq!(mbx.recv.len(), 6, "{mode}: {} {}", mbx.link, mbx.dir);
            }
            check(&a, std::slice::from_ref(&b)).unwrap();
        }
    }

    #[test]
    fn interleaved_reference_covers_the_ring() {
        let mut o = opts(2, 4, "topk:10");
        o.schedule = Schedule::Interleaved { v: 2 };
        let a = run_reference(&o).unwrap();
        let b = run_reference(&o).unwrap();
        assert_eq!(a.boxes, b.boxes, "interleaved reference must be deterministic");
        // ring topology: 2 physical links x 2 dirs
        assert_eq!(a.boxes.len(), 4);
        // 3 boundaries x 4 mb per direction: the chain link carries
        // boundaries 0 and 2 (8 messages), the wrap link boundary 1
        assert_eq!(a.boxes[0].recv.len(), 8, "link 0 fwd");
        assert_eq!(a.boxes[2].recv.len(), 4, "wrap link fwd");
        assert_eq!(a.boxes[1].recv.len(), 8, "link 0 bwd");
        assert_eq!(a.boxes[3].recv.len(), 4, "wrap link bwd");
        check(&a, std::slice::from_ref(&b)).unwrap();
        // keys on a shared link are chunk-qualified: all unique
        for mbx in &a.boxes {
            let mut keys: Vec<u64> = mbx.recv.iter().map(|r| r.0).collect();
            keys.sort_unstable();
            keys.dedup();
            assert_eq!(keys.len(), mbx.recv.len(), "link {} {}", mbx.link, mbx.dir);
        }
    }

    #[test]
    fn interleaved_v1_reference_matches_plain_1f1b() {
        // the worker-level half of the v=1 pin: same mailboxes, same
        // delivery logs, same bytes as the flat 1F1B run
        for mode in ["topk:10", "ef21+topk:10"] {
            let mut flat = opts(3, 6, mode);
            flat.schedule = Schedule::OneFOneB;
            flat.steps = 2;
            let mut il = flat.clone();
            il.schedule = Schedule::Interleaved { v: 1 };
            let a = run_reference(&flat).unwrap();
            let b = run_reference(&il).unwrap();
            assert_eq!(a.boxes, b.boxes, "{mode}: v=1 diverged from 1f1b");
        }
    }

    #[test]
    fn interleaved_feedback_runs_per_channel_state() {
        // EF21 over the ring: per-(link, dir, chunk) generations stay
        // consistent, so repeated steps decode cleanly and determinism
        // holds end to end
        let mut o = opts(2, 4, "ef21+topk:10");
        o.schedule = Schedule::Interleaved { v: 2 };
        o.steps = 3;
        let a = run_reference(&o).unwrap();
        let b = run_reference(&o).unwrap();
        assert_eq!(a.boxes, b.boxes);
        for mbx in &a.boxes {
            assert!(mbx.recv.len() == 12 || mbx.recv.len() == 24, "{}", mbx.recv.len());
        }
        // and the byte-saving claim survives interleaving
        let mut base = o.clone();
        base.spec = Spec::parse("topk:10").unwrap();
        base.link_elems = 4096;
        let mut ef = base.clone();
        ef.spec = Spec::parse("ef21+topk:10").unwrap();
        let base_run = run_reference(&base).unwrap();
        let ef_run = run_reference(&ef).unwrap();
        let (b0, c0) = compare_bytes(&base_run, &[ef_run]).unwrap();
        assert!(c0 < b0, "interleaved ef21 {c0} !< baseline {b0}");
    }

    #[test]
    fn interleaved_rejects_indivisible_microbatches() {
        let mut o = opts(2, 3, "none");
        o.schedule = Schedule::Interleaved { v: 2 };
        assert!(run_reference(&o).is_err());
    }

    #[test]
    fn multi_step_runs_repeat_the_schedule_with_unique_keys() {
        let mut o = opts(2, 2, "none");
        o.steps = 3;
        let s = run_reference(&o).unwrap();
        for mbx in &s.boxes {
            assert_eq!(mbx.recv.len(), 6);
            let keys: Vec<u64> = mbx.recv.iter().map(|r| r.0).collect();
            let mut sorted = keys.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 6, "transport keys must be unique: {keys:?}");
        }
    }

    /// Acceptance pin: measured wire bytes under EF21 + Top10% (and
    /// AQ-SGD once its buffers are warm) are strictly below the
    /// feedback=none TopK baseline — the inversion PR 2 had is gone.
    #[test]
    fn error_feedback_cuts_wire_bytes_below_plain_topk() {
        let big = |mode: &str| {
            let mut o = opts(2, 4, mode);
            o.link_elems = 4096;
            o.steps = 10;
            o
        };
        let base = run_reference(&big("topk:10")).unwrap();
        let ef = run_reference(&big("ef21+topk:10")).unwrap();
        let (b, c) = compare_bytes(&base, std::slice::from_ref(&ef)).unwrap();
        assert!(c < b, "ef21 {c} !< baseline {b}");
        // EF21 runs the delta protocol in both directions: every
        // mailbox individually ships less
        for (eb, bb) in ef.boxes.iter().zip(&base.boxes) {
            assert!(eb.sent_bytes < bb.sent_bytes, "{} {}", eb.link, eb.dir);
        }
        let aq = run_reference(&big("aqsgd+topk:10")).unwrap();
        let (b2, c2) = compare_bytes(&base, std::slice::from_ref(&aq)).unwrap();
        assert!(c2 < b2, "aqsgd {c2} !< baseline {b2}");
        // activations: bootstraps amortize into near-zero deltas;
        // gradients fall back to plain TopK (equal bytes)
        assert!(aq.boxes[0].sent_bytes < base.boxes[0].sent_bytes);
        assert_eq!(aq.boxes[1].sent_bytes, base.boxes[1].sent_bytes);
        // and a same-cost candidate fails the strict check
        assert!(compare_bytes(&base, std::slice::from_ref(&base)).is_err());
    }

    #[test]
    fn aqsgd_bootstraps_once_then_ships_deltas() {
        let mut o = opts(2, 2, "aqsgd+topk:10");
        o.steps = 3;
        let s = run_reference(&o).unwrap();
        let fwd = &s.boxes[0];
        let boot = wire::delta_bootstrap_bytes(o.link_elems);
        // step 1: both microbatches bootstrap at full size
        assert_eq!(fwd.recv[0].1, boot);
        assert_eq!(fwd.recv[1].1, boot);
        // repeated identical samples: zero deltas, near-empty frames
        for r in &fwd.recv[2..] {
            assert!(r.1 < 64, "update frame {} B should be near-empty", r.1);
        }
    }

    #[test]
    fn summary_json_roundtrip() {
        let s = run_reference(&opts(2, 3, "quant:fw4-bw6")).unwrap();
        let j = s.to_json().to_string();
        let back = WorkerSummary::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.boxes, s.boxes);
        assert_eq!(back.rank, None);
        check(&s, &[back]).unwrap();
    }

    #[test]
    fn check_flags_divergence() {
        let a = run_reference(&opts(2, 2, "topk:10")).unwrap();
        let mut bad = a.clone();
        bad.boxes[0].recv[0].2 ^= 1; // flip one digest bit
        assert!(check(&a, &[bad]).is_err());
        let mut short = a.clone();
        short.boxes[1].recv.pop(); // lose a message
        assert!(check(&a, &[short]).is_err());
    }

    /// dp > 1 appends one allreduce round per schedule round: every hop
    /// mailbox logs exactly one extra frame per ring step, keyed in the
    /// high-bit space, and the run stays deterministic.
    #[test]
    fn dp_reference_runs_the_allreduce_phase_deterministically() {
        for mode in ["none", "topk:10", "quant:fw8-bw6", "ef21+topk:10"] {
            let mut o = opts(2, 2, mode);
            o.dp = 2;
            o.steps = 3;
            let a = run_reference(&o).unwrap_or_else(|e| panic!("{mode}: {e}"));
            let b = run_reference(&o).unwrap();
            assert_eq!(a.boxes, b.boxes, "{mode}: dp run not deterministic");
            check(&a, std::slice::from_ref(&b)).unwrap();
            // 2 replicas x 2 ring steps per round: the fwd chain hop and
            // the bwd wrap hop each carry (2 schedule mb + 2 ar frames)
            // x 3 rounds
            for mbx in &a.boxes {
                assert_eq!(
                    mbx.recv.len(),
                    12,
                    "{mode}: link {} {} saw {} frames",
                    mbx.link,
                    mbx.dir,
                    mbx.recv.len()
                );
                let ar: Vec<u64> =
                    mbx.recv.iter().map(|r| r.0).filter(|k| k & (1 << 63) != 0).collect();
                assert_eq!(ar.len(), 6, "{mode}: one ar frame per round per ring step");
                let mut uniq = ar.clone();
                uniq.sort_unstable();
                uniq.dedup();
                assert_eq!(uniq.len(), ar.len(), "{mode}: ar keys must be unique");
            }
        }
    }

    /// dp = 1 is byte-identical to a run built before the field existed:
    /// the allreduce phase must not touch anything.
    #[test]
    fn dp1_worker_is_bit_identical_to_plain() {
        let mut o = opts(3, 4, "ef21+topk:10");
        o.steps = 2;
        let plain = run_reference(&o).unwrap();
        let mut dp1 = o.clone();
        dp1.dp = 1;
        let b = run_reference(&dp1).unwrap();
        assert_eq!(plain.boxes, b.boxes);
    }

    #[test]
    fn dp_parity_sim_vs_uds_loopback() {
        // the allreduce mailbox half of the --reference/--check contract
        for mode in ["topk:10", "ef21+topk:10"] {
            let mut o = opts(2, 2, mode);
            o.dp = 2;
            o.steps = 2;
            o.link_elems = 256;
            let reference = run_reference(&o).unwrap();
            let loopback = run_loopback(&o, Backend::Uds).unwrap();
            check(&reference, std::slice::from_ref(&loopback))
                .unwrap_or_else(|e| panic!("{mode}: {e}"));
        }
    }

    #[test]
    fn dp_interleaved_ring_carries_the_wrap_hop() {
        let mut o = opts(2, 4, "topk:10");
        o.schedule = Schedule::Interleaved { v: 2 };
        o.dp = 2;
        let a = run_reference(&o).unwrap();
        let b = run_reference(&o).unwrap();
        assert_eq!(a.boxes, b.boxes);
        // with v > 1 the wrap hop rides the ring's wrap link fwd mailbox
        // instead of link 0 bwd: wrap fwd = 4 schedule + 2 ar frames
        assert_eq!(a.boxes[2].recv.len(), 6, "wrap link fwd");
        assert_eq!(a.boxes[3].recv.len(), 4, "wrap link bwd stays schedule-only");
    }

    #[test]
    fn dp_misconfigurations_are_typed_errors() {
        // dp must equal stages
        let mut o = opts(3, 4, "none");
        o.dp = 2;
        assert!(run_reference(&o).is_err());
        // a flat chain deeper than 2 has no wire for the wrap hop
        let mut o = opts(3, 6, "none");
        o.dp = 3;
        let err = run_reference(&o).unwrap_err().to_string();
        assert!(err.contains("wrap hop"), "{err}");
        // ... but the interleaved ring topology carries it
        let mut o = opts(3, 6, "none");
        o.schedule = Schedule::Interleaved { v: 2 };
        o.dp = 3;
        run_reference(&o).unwrap();
    }

    #[test]
    fn fnv_digest_is_stable() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a(b"ab"), fnv1a(b"ba"));
    }

    fn knobs(rate_rps: f64, requests: usize) -> ServeKnobs {
        ServeKnobs { rate_rps, requests, max_batch: 4, deadline_s: 0.02 }
    }

    #[test]
    fn serve_reference_is_deterministic_and_forward_only() {
        let o = opts(3, 4, "topk:10");
        let k = knobs(500.0, 12);
        let a = run_serve_reference(&o, &k).unwrap();
        let b = run_serve_reference(&o, &k).unwrap();
        assert_eq!(a.boxes, b.boxes, "same seed+rate must replay bit-identically");
        let (_, nb) = serve_schedule(&o, &k);
        assert!(nb >= 3, "12 requests with max_batch 4 form at least 3 batches");
        for mbx in &a.boxes {
            match mbx.dir {
                Dir::Fwd => {
                    assert_eq!(mbx.recv.len(), nb, "one activation per admitted batch");
                    assert_eq!(mbx.sent_msgs as usize, nb);
                }
                Dir::Bwd => {
                    assert!(mbx.recv.is_empty(), "serving ships no gradients");
                    assert_eq!(mbx.sent_msgs, 0);
                }
            }
        }
        // a different arrival seed changes the admitted composition
        let mut o2 = o.clone();
        o2.seed = 12;
        let c = run_serve_reference(&o2, &k).unwrap();
        assert_ne!(a.boxes, c.boxes);
    }

    #[test]
    fn serve_parity_sim_vs_uds_loopback() {
        // the serve half of the --reference/--check contract: identical
        // microbatch composition and bit-identical mailbox logs across
        // the simulator and a real-socket loopback run
        for mode in ["topk:10", "ef21+topk:10"] {
            let mut o = opts(2, 4, mode);
            o.link_elems = 256;
            let k = knobs(500.0, 8);
            let reference = run_serve_reference(&o, &k).unwrap();
            let loopback = run_serve_loopback(&o, &k, Backend::Uds).unwrap();
            check(&reference, std::slice::from_ref(&loopback))
                .unwrap_or_else(|e| panic!("{mode}: {e}"));
            assert!(loopback.wire_elapsed_s > 0.0, "{mode}: real wire time measured");
        }
    }

    #[test]
    fn serve_interleaved_needs_no_mb_divisibility() {
        // training interleaved:2 rejects mb=3; serving admits any count
        let mut o = opts(2, 3, "topk:10");
        o.schedule = Schedule::Interleaved { v: 2 };
        assert!(run_reference(&o).is_err(), "training path still validates");
        let s = run_serve_reference(&o, &knobs(5000.0, 3)).unwrap();
        let fwd_msgs: usize =
            s.boxes.iter().filter(|b| b.dir == Dir::Fwd).map(|b| b.recv.len()).sum();
        // 2 ranks x v=2 -> 3 wired boundaries per batch over the ring
        assert!(fwd_msgs > 0 && fwd_msgs % 3 == 0, "{fwd_msgs}");
    }
}
