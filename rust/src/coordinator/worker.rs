//! `mpcomp worker` — run one pipeline stage as its own OS process,
//! exchanging real compressed activations/gradients over the socket
//! transport.
//!
//! Each rank walks the same {GPipe, 1F1B} schedule and executes only
//! its stage's ops: a forward op receives the activation frame from the
//! previous rank (blocking on the real mailbox) and sends the stage's
//! output activation downstream; a backward op receives the gradient
//! frame from the next rank and sends upstream. Message tensors are
//! generated deterministically from `(seed, link, dir, mb)` and
//! compressed with the configured (stateless) spec through the actual
//! wire codecs, so the bytes on the socket are exactly what the trainer
//! would ship — without needing the AOT artifacts, which makes the
//! multi-process path runnable everywhere (including the CI `loopback`
//! job).
//!
//! Every run produces a [`WorkerSummary`]: per-`(link, dir)` mailbox
//! logs of `(key, bytes, payload digest)` in delivery order plus sent
//! totals. [`run_reference`] produces the same summary from a
//! single-process `SimNet` replay, and [`check`] asserts a set of
//! worker summaries is bit-identical to it — same per-mailbox message
//! ordering, byte counts, and payload digests — which is the sim/real
//! parity contract CI enforces across two OS processes.

use anyhow::{bail, Context, Result};

use crate::compression::{ops, wire, Feedback, Method, Spec};
use crate::config::Schedule;
use crate::coordinator::pipeline::{self, Op};
use crate::netsim::{
    Backend, Dir, Payload, RealTransport, Rendezvous, SimNet, Transport, WireModel,
};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Parameters of one synthetic multi-process schedule run.
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// Pipeline depth == world size (one process per stage).
    pub stages: usize,
    pub mb: usize,
    /// Elements per inter-stage tensor.
    pub link_elems: usize,
    pub schedule: Schedule,
    /// Compression spec; stateless modes only (none / quant / plain topk).
    pub spec: Spec,
    pub seed: u64,
    pub wire: WireModel,
    pub recv_timeout_s: f64,
}

/// What one endpoint saw on one `(link, dir)` mailbox.
#[derive(Clone, Debug, PartialEq)]
pub struct MailboxLog {
    pub link: usize,
    pub dir: Dir,
    /// `(key, bytes, payload digest)` in delivery order.
    pub recv: Vec<(u64, usize, u64)>,
    pub sent_msgs: u64,
    pub sent_bytes: u64,
}

/// The deterministic outcome of one worker (or reference) run.
#[derive(Clone, Debug)]
pub struct WorkerSummary {
    pub backend: String,
    /// `None` for the single-process reference run (all stages).
    pub rank: Option<usize>,
    /// One log per `(link, dir)`, index `link * 2 + dir`.
    pub boxes: Vec<MailboxLog>,
    /// Measured wall-clock tx time (0 for the reference).
    pub wire_elapsed_s: f64,
}

/// FNV-1a over a payload — the digest [`check`] compares across ranks.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Deterministic synthetic tensor for the message `(link, dir, mb)`.
fn gen_tensor(opts: &WorkerOpts, link: usize, dir: Dir, mb: usize) -> Vec<f32> {
    let tag = ((link as u64) << 40) | ((dir.index() as u64) << 32) | mb as u64;
    let mut rng = Rng::with_stream(opts.seed, tag);
    let mut v = vec![0.0f32; opts.link_elems];
    rng.fill_normal(&mut v, 0.0, 1.0);
    v
}

/// Compress + encode the message for `(link, dir, mb)` with the actual
/// wire codecs (what the trainer's links put on a real socket).
fn encode_message(opts: &WorkerOpts, link: usize, dir: Dir, mb: usize) -> Result<Vec<u8>> {
    let x = gen_tensor(opts, link, dir, mb);
    match opts.spec.method {
        Method::None => Ok(wire::encode_raw(&x)),
        Method::Quant { fw_bits, bw_bits } => {
            let bits = if dir == Dir::Fwd { fw_bits } else { bw_bits };
            Ok(wire::encode_quant(&x, bits))
        }
        Method::TopK { frac, shared_idx, feedback } => {
            if shared_idx || feedback != Feedback::None {
                bail!(
                    "worker runs stateless compression only (got '{}'); \
                     feedback state replication is a trainer concern",
                    opts.spec.label()
                );
            }
            let (dense, _) = ops::topk(&x, frac);
            let k = dense.iter().filter(|&&v| v != 0.0).count();
            Ok(wire::encode_sparse(&dense, k))
        }
    }
}

/// Walk the schedule, executing send/recv for every stage `mine`
/// accepts, and log what each mailbox saw. With `mine = |_| true` and a
/// `SimNet` (or loopback real transport) this is the single-process
/// replay; with `mine = |s| s == rank` over an endpoint transport it is
/// one rank of a multi-process run.
fn run_stages(
    opts: &WorkerOpts,
    net: &mut dyn Transport,
    mine: &dyn Fn(usize) -> bool,
) -> Result<Vec<MailboxLog>> {
    let stages = opts.stages;
    let links = stages.saturating_sub(1);
    let mut boxes: Vec<MailboxLog> = (0..links)
        .flat_map(|link| {
            [Dir::Fwd, Dir::Bwd].into_iter().map(move |dir| MailboxLog {
                link,
                dir,
                recv: Vec::new(),
                sent_msgs: 0,
                sent_bytes: 0,
            })
        })
        .collect();
    // payload digests recorded at send time, for backends whose frames
    // carry no payload (the SimNet reference)
    let mut sent_digests: Vec<std::collections::HashMap<u64, u64>> =
        (0..links * 2).map(|_| Default::default()).collect();

    let ops = pipeline::ops_for(opts.schedule, stages, opts.mb);
    for op in &ops {
        let (stage, mb, dir) = match *op {
            Op::Fwd { stage, mb } => (stage, mb, Dir::Fwd),
            Op::Bwd { stage, mb } => (stage, mb, Dir::Bwd),
        };
        if !mine(stage) {
            continue;
        }
        let key = mb as u64;
        // receive this op's input frame (if the stage has an input link)
        let recv_link = match dir {
            Dir::Fwd => stage.checked_sub(1),
            Dir::Bwd => {
                if stage + 1 < stages {
                    Some(stage)
                } else {
                    None
                }
            }
        };
        if let Some(link) = recv_link {
            let slot = link * 2 + dir.index();
            let frame = net
                .recv(link, dir, key)
                .with_context(|| format!("rank recv link {link} {dir} mb {mb}"))?;
            let digest = match &frame.payload {
                Some(p) => fnv1a(p),
                None => *sent_digests[slot]
                    .get(&key)
                    .context("sim reference: recv before send")?,
            };
            boxes[slot].recv.push((key, frame.bytes, digest));
        }
        // send this op's output frame (if the stage has an output link)
        let send_link = match dir {
            Dir::Fwd => {
                if stage + 1 < stages {
                    Some(stage)
                } else {
                    None
                }
            }
            Dir::Bwd => stage.checked_sub(1),
        };
        if let Some(link) = send_link {
            let slot = link * 2 + dir.index();
            let buf = encode_message(opts, link, dir, mb)?;
            sent_digests[slot].insert(key, fnv1a(&buf));
            let raw = wire::raw_wire_bytes(opts.link_elems);
            net.send(link, dir, key, Payload::Bytes(&buf), raw, 0.0)
                .with_context(|| format!("rank send link {link} {dir} mb {mb}"))?;
            boxes[slot].sent_msgs += 1;
            boxes[slot].sent_bytes += buf.len() as u64;
        }
    }
    Ok(boxes)
}

/// Single-process reference: the whole schedule over `SimNet`.
pub fn run_reference(opts: &WorkerOpts) -> Result<WorkerSummary> {
    let mut net = SimNet::new(opts.stages.saturating_sub(1), opts.wire);
    let boxes = run_stages(opts, &mut net, &|_| true)?;
    Ok(WorkerSummary { backend: "sim".into(), rank: None, boxes, wire_elapsed_s: 0.0 })
}

/// Single-process run over a real loopback transport (both ends of
/// every link in this process) — the in-test analogue of the
/// multi-process path.
pub fn run_loopback(opts: &WorkerOpts, backend: Backend) -> Result<WorkerSummary> {
    let links = opts.stages.saturating_sub(1);
    let timeout = std::time::Duration::from_secs_f64(opts.recv_timeout_s);
    let mut net = RealTransport::loopback(links, backend, opts.wire, timeout)?;
    let boxes = run_stages(opts, &mut net, &|_| true)?;
    let elapsed = net.wire_elapsed_s();
    net.shutdown()?;
    Ok(WorkerSummary {
        backend: backend.name().into(),
        rank: None,
        boxes,
        wire_elapsed_s: elapsed,
    })
}

/// One rank of a multi-process run: rendezvous with the neighbor
/// processes, execute this stage's ops, shut down gracefully.
pub fn run_rank(
    opts: &WorkerOpts,
    rank: usize,
    backend: Backend,
    rendezvous_addr: &str,
) -> Result<WorkerSummary> {
    if rank >= opts.stages {
        bail!("rank {rank} out of range for {} stages", opts.stages);
    }
    let mut rv = Rendezvous::parse(backend, opts.stages, rendezvous_addr)?;
    rv.recv_timeout = std::time::Duration::from_secs_f64(opts.recv_timeout_s);
    let mut net = RealTransport::endpoint(&rv, rank, opts.wire)?;
    let boxes = run_stages(opts, &mut net, &|s| s == rank)?;
    let elapsed = net.wire_elapsed_s();
    net.shutdown()?;
    Ok(WorkerSummary {
        backend: backend.name().into(),
        rank: Some(rank),
        boxes,
        wire_elapsed_s: elapsed,
    })
}

/// Assert worker summaries are bit-identical to the reference run:
/// every mailbox a worker received must match the reference's ordered
/// `(key, bytes, digest)` log exactly, every sender must have charged
/// the same bytes, and together the workers must cover every message
/// the reference saw.
pub fn check(reference: &WorkerSummary, workers: &[WorkerSummary]) -> Result<()> {
    for w in workers {
        if w.boxes.len() != reference.boxes.len() {
            bail!(
                "worker {:?}: {} mailboxes, reference has {}",
                w.rank,
                w.boxes.len(),
                reference.boxes.len()
            );
        }
        for (wb, rb) in w.boxes.iter().zip(&reference.boxes) {
            if !wb.recv.is_empty() && wb.recv != rb.recv {
                bail!(
                    "worker {:?} link {} {}: delivery log diverged\n  got:  {:?}\n  want: {:?}",
                    w.rank,
                    wb.link,
                    wb.dir,
                    wb.recv,
                    rb.recv
                );
            }
            if wb.sent_msgs > 0
                && (wb.sent_msgs != rb.sent_msgs || wb.sent_bytes != rb.sent_bytes)
            {
                bail!(
                    "worker {:?} link {} {}: sent {} msgs / {} B, reference {} msgs / {} B",
                    w.rank,
                    wb.link,
                    wb.dir,
                    wb.sent_msgs,
                    wb.sent_bytes,
                    rb.sent_msgs,
                    rb.sent_bytes
                );
            }
        }
    }
    for (i, rb) in reference.boxes.iter().enumerate() {
        let got: usize = workers.iter().map(|w| w.boxes[i].recv.len()).sum();
        if got != rb.recv.len() {
            bail!(
                "link {} {}: workers received {got} messages, reference saw {}",
                rb.link,
                rb.dir,
                rb.recv.len()
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// summary (de)serialization — the CI job diffs rank files via `--check`
// ---------------------------------------------------------------------------

impl WorkerSummary {
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("backend", Json::Str(self.backend.clone()));
        o.set("rank", self.rank.map_or(Json::Null, |r| Json::Num(r as f64)));
        o.set("wire_elapsed_s", Json::Num(self.wire_elapsed_s));
        let boxes: Vec<Json> = self
            .boxes
            .iter()
            .map(|b| {
                let mut jb = Json::object();
                jb.set("link", Json::Num(b.link as f64));
                jb.set("dir", Json::Str(b.dir.name().into()));
                jb.set("sent_msgs", Json::Num(b.sent_msgs as f64));
                jb.set("sent_bytes", Json::Num(b.sent_bytes as f64));
                let recv: Vec<Json> = b
                    .recv
                    .iter()
                    .map(|(key, bytes, digest)| {
                        let mut jr = Json::object();
                        jr.set("key", Json::Num(*key as f64));
                        jr.set("bytes", Json::Num(*bytes as f64));
                        // digests exceed f64's integer range: hex string
                        jr.set("digest", Json::Str(format!("{digest:016x}")));
                        jr
                    })
                    .collect();
                jb.set("recv", Json::Arr(recv));
                jb
            })
            .collect();
        o.set("boxes", Json::Arr(boxes));
        o
    }

    pub fn from_json(j: &Json) -> Result<WorkerSummary> {
        let rank = match j.get("rank")? {
            Json::Null => None,
            v => Some(v.usize()?),
        };
        let mut boxes = Vec::new();
        for jb in j.get("boxes")?.arr()? {
            let mut recv = Vec::new();
            for jr in jb.get("recv")?.arr()? {
                let key = jr.get("key")?.num()? as u64;
                let bytes = jr.get("bytes")?.usize()?;
                let digest = u64::from_str_radix(jr.get("digest")?.str()?, 16)
                    .context("bad digest hex")?;
                recv.push((key, bytes, digest));
            }
            boxes.push(MailboxLog {
                link: jb.get("link")?.usize()?,
                dir: Dir::parse(jb.get("dir")?.str()?)?,
                recv,
                sent_msgs: jb.get("sent_msgs")?.num()? as u64,
                sent_bytes: jb.get("sent_bytes")?.num()? as u64,
            });
        }
        Ok(WorkerSummary {
            backend: j.get("backend")?.str()?.to_string(),
            rank,
            boxes,
            wire_elapsed_s: j.get("wire_elapsed_s")?.num()?,
        })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {path}"))
    }

    pub fn load(path: &str) -> Result<WorkerSummary> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        WorkerSummary::from_json(&Json::parse(&text)?)
    }

    /// Total messages this endpoint received.
    pub fn received(&self) -> usize {
        self.boxes.iter().map(|b| b.recv.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(stages: usize, mb: usize, mode: &str) -> WorkerOpts {
        WorkerOpts {
            stages,
            mb,
            link_elems: 64,
            schedule: Schedule::GPipe,
            spec: Spec::parse(mode).unwrap(),
            seed: 11,
            wire: WireModel::datacenter(),
            recv_timeout_s: 5.0,
        }
    }

    #[test]
    fn reference_is_deterministic_and_self_consistent() {
        let o = opts(3, 4, "topk:10");
        let a = run_reference(&o).unwrap();
        let b = run_reference(&o).unwrap();
        assert_eq!(a.boxes, b.boxes);
        // 2 links x 2 dirs, every mailbox saw all 4 microbatches
        assert_eq!(a.boxes.len(), 4);
        for mbx in &a.boxes {
            assert_eq!(mbx.recv.len(), 4, "link {} {}", mbx.link, mbx.dir);
            assert_eq!(mbx.sent_msgs, 4);
        }
        check(&a, std::slice::from_ref(&b)).unwrap();
    }

    #[test]
    fn reference_changes_with_seed_and_spec() {
        let a = run_reference(&opts(2, 2, "topk:10")).unwrap();
        let mut o = opts(2, 2, "topk:10");
        o.seed = 12;
        let b = run_reference(&o).unwrap();
        assert_ne!(a.boxes, b.boxes, "digests must depend on the seed");
        let c = run_reference(&opts(2, 2, "none")).unwrap();
        assert_ne!(
            a.boxes[0].sent_bytes, c.boxes[0].sent_bytes,
            "topk must ship fewer bytes than raw"
        );
    }

    #[test]
    fn feedback_specs_are_rejected() {
        let o = opts(2, 2, "ef21+topk:10");
        assert!(run_reference(&o).is_err());
    }

    #[test]
    fn summary_json_roundtrip() {
        let s = run_reference(&opts(2, 3, "quant:fw4-bw6")).unwrap();
        let j = s.to_json().to_string();
        let back = WorkerSummary::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.boxes, s.boxes);
        assert_eq!(back.rank, None);
        check(&s, &[back]).unwrap();
    }

    #[test]
    fn check_flags_divergence() {
        let a = run_reference(&opts(2, 2, "topk:10")).unwrap();
        let mut bad = a.clone();
        bad.boxes[0].recv[0].2 ^= 1; // flip one digest bit
        assert!(check(&a, &[bad]).is_err());
        let mut short = a.clone();
        short.boxes[1].recv.pop(); // lose a message
        assert!(check(&a, &[short]).is_err());
    }

    #[test]
    fn fnv_digest_is_stable() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a(b"ab"), fnv1a(b"ba"));
    }
}
