//! Execute a pipeline schedule through the [`Transport`] and measure
//! its makespan — the successor of the analytic `pipeline::makespan`
//! estimate.
//!
//! The executor walks the schedule in order, keeping one clock per
//! rank. A forward op whose model chunk has an upstream boundary starts
//! no earlier than the arrival of its input activations (sent when the
//! upstream chunk finished producing them); a backward op is gated the
//! same way on the gradient message. On the default [`SimNet`] backend
//! messages contend for link bandwidth and respect the bounded
//! in-flight window, so — unlike the analytic model — bursts of traffic
//! (GPipe's all-forward phase) are charged their queueing delay, and
//! with interleaved schedules the chunks sharing one physical link
//! genuinely contend (each boundary keys its messages separately, but
//! they serialize on the same [`SimNet`] channel). On the real backends
//! ([`simulate_real`]) frames of the scheduled sizes actually cross
//! loopback kernel sockets and the report's busy/elapsed columns are
//! measured wall-clock I/O time.
//!
//! With zero latency and no contention the simulated model agrees with
//! the analytic one *exactly*; the property tests below pin that
//! equivalence — for the flat schedules and the interleaved ring alike
//! — which is the correctness anchor for everything the simulator
//! reports.

use std::time::Duration;

use crate::coordinator::pipeline::{self, Op};
use crate::netsim::{
    Backend, Dir, FaultModel, Payload, RealTransport, SimNet, Transport, TransportError,
    WireModel,
};

/// Static description of one simulated pipeline run.
#[derive(Clone, Debug)]
pub struct SimSpec {
    /// Worker (process) count; model stages = `n_stages * v`.
    pub n_stages: usize,
    /// Virtual stages (model chunks) per rank — 1 for GPipe/1F1B.
    pub v: usize,
    /// Microbatches per optimizer step.
    pub n_mb: usize,
    /// Compute cost of one forward op (one chunk's forward).
    pub fwd_op_s: f64,
    /// Compute cost of one backward op (one chunk's backward).
    pub bwd_op_s: f64,
    /// Extra forward recomputation charged per backward op (GPipe's
    /// rematerialization: it discards activations it cannot afford to
    /// stash for all `n_mb` microbatches and recomputes them in the
    /// backward phase; 1F1B's depth-bounded stash avoids this).
    pub recompute_s: f64,
    /// Payload bytes per forward (activation) message, per **stage
    /// boundary** (`pipeline::num_boundaries` entries). Boundaries
    /// sharing a ring link may carry differently-compressed messages —
    /// the planner's per-channel specs — while still contending for the
    /// same physical link's bandwidth and in-flight window.
    pub fwd_bytes: Vec<usize>,
    /// Payload bytes per backward (gradient) message, per boundary.
    pub bwd_bytes: Vec<usize>,
    /// Uncompressed payload bytes per message, per boundary (ledger).
    pub raw_bytes: Vec<usize>,
    /// Bandwidth/latency of every link.
    pub model: WireModel,
    /// Bounded in-flight window per link direction.
    pub capacity: usize,
    /// Per-link fault model (drops/dups/reorder/jitter/stragglers);
    /// `None` runs the exact lossless simulator.
    pub faults: Option<FaultModel>,
}

impl SimSpec {
    /// Physical wire links this spec's topology needs (chain for flat
    /// schedules, ring once chunks interleave).
    pub fn wire_links(&self) -> usize {
        pipeline::num_wire_links(self.n_stages, self.v)
    }

    /// Stage boundaries the byte vectors are indexed by.
    pub fn boundaries(&self) -> usize {
        pipeline::num_boundaries(self.n_stages, self.v)
    }
}

/// Measured outcome of one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct SimReport {
    /// End-to-end time of the schedule (max worker clock; wall time of
    /// the last wire event on real backends).
    pub makespan_s: f64,
    /// Bandwidth-occupancy seconds summed over channels (no latency);
    /// measured socket-write seconds on real backends.
    pub busy_s: f64,
    /// Sum of per-message wire times (latency + serialization) — the
    /// pre-simulator accounting metric, kept for comparison.
    pub wire_sum_s: f64,
    /// Compressed bytes that crossed the wire.
    pub bytes: u64,
    /// Uncompressed-equivalent bytes (the ledger's raw column).
    pub raw_bytes: u64,
    /// Measured wall-clock tx time (0 on the simulator).
    pub wire_elapsed_s: f64,
}

/// Run `ops` through a fresh `SimNet` described by `spec`.
pub fn simulate(ops: &[Op], spec: &SimSpec) -> SimReport {
    let mut net = SimNet::with_capacity(spec.wire_links(), spec.model, spec.capacity);
    if let Some(fm) = &spec.faults {
        net.set_faults(fm.clone());
    }
    simulate_transport(ops, spec, &mut net).expect("SimNet delivers every scheduled message")
}

/// Run `ops` over a real loopback transport (tcp/uds/udp): frames of
/// the scheduled sizes actually cross kernel sockets. The udp backend
/// reads its fault-injection knobs from the `MPCOMP_UDP_*` environment.
pub fn simulate_real(
    ops: &[Op],
    spec: &SimSpec,
    backend: Backend,
) -> Result<SimReport, TransportError> {
    let timeout = Duration::from_secs(20);
    if backend == Backend::Udp {
        let faults = crate::netsim::UdpFaults::from_env();
        let mut net =
            crate::netsim::UdpTransport::loopback(spec.wire_links(), spec.model, timeout, &faults)?;
        let report = simulate_transport(ops, spec, &mut net)?;
        net.shutdown()?;
        return Ok(report);
    }
    let mut net = RealTransport::loopback(spec.wire_links(), backend, spec.model, timeout)?;
    let report = simulate_transport(ops, spec, &mut net)?;
    net.shutdown()?;
    Ok(report)
}

/// Hybrid DP×PP: `dp` data-parallel replicas of the pipeline described
/// by `pp`, each followed by a per-stage compressed ring-allreduce of
/// its gradient shard (the source paper's gradient-tolerance finding
/// only pays off once this traffic dominates — see `exp scale`).
#[derive(Clone, Debug)]
pub struct HybridSpec {
    /// The per-replica pipeline run.
    pub pp: SimSpec,
    /// Data-parallel replica count; 1 degenerates to the plain pipeline
    /// (bit-identical [`simulate`] report, pinned by test).
    pub dp: usize,
    /// Gradient elements allreduced per pipeline rank per step.
    pub grad_elems: usize,
    /// Compression on the allreduce (gradient) channels.
    pub grad_spec: crate::compression::Spec,
}

impl HybridSpec {
    /// Total simulated ranks: pipeline stages × data-parallel replicas.
    pub fn ranks(&self) -> usize {
        self.pp.n_stages * self.dp
    }
}

/// Wire bytes of one ring-allreduce hop carrying a `seg_elems`-element
/// segment under `spec`: the gradient-direction codec size wrapped in
/// the tag-5 envelope.
pub fn allreduce_hop_bytes(spec: &crate::compression::Spec, seg_elems: usize) -> usize {
    crate::compression::wire::allreduce_wire_bytes(spec_wire_bytes(spec, seg_elems).1)
}

/// Segment a replica ships at global ring `step` (reduce-scatter then
/// all-gather) — mirrors `coordinator::allreduce::ReplicaRing::send_seg`.
fn ar_send_seg(dp: usize, r: usize, step: usize) -> usize {
    if step < dp - 1 {
        (r + dp - step % dp) % dp
    } else {
        let s = step - (dp - 1);
        (r + 1 + dp - s % dp) % dp
    }
}

/// Simulate the hybrid DP×PP step: the pipeline phase once (replicas
/// are identical), then all `n_stages * dp` allreduce rings
/// concurrently through one event-core [`SimNet`] — link `s*dp + r`
/// carries stage `s`'s hop from replica `r` to `r+1`, and a replica's
/// next hop is gated on the previous hop's arrival, exactly like the
/// live rings in `coordinator::allreduce`. This is the path `exp
/// scale` drives to 256–512 ranks, so it leans on the keyed-mailbox
/// event core rather than a per-message linear scan.
pub fn simulate_hybrid(ops: &[Op], spec: &HybridSpec) -> SimReport {
    let pp = simulate(ops, &spec.pp);
    if spec.dp <= 1 {
        return pp;
    }
    let (dp, stages, elems) = (spec.dp, spec.pp.n_stages, spec.grad_elems);
    let links = stages * dp;
    let mut net = SimNet::with_capacity(links, spec.pp.model, spec.pp.capacity);
    if let Some(fm) = &spec.pp.faults {
        net.set_faults(fm.clone());
    }
    // every replica's pipeline finishes at the same (simulated) time
    for rank in 0..links {
        net.advance(rank, pp.makespan_s);
    }
    let seg_len = |seg: usize| (seg + 1) * elems / dp - seg * elems / dp;
    for step in 0..2 * (dp - 1) {
        for link in 0..links {
            let n = seg_len(ar_send_seg(dp, link % dp, step));
            let hop = allreduce_hop_bytes(&spec.grad_spec, n);
            let raw = crate::compression::wire::allreduce_wire_bytes(
                crate::compression::wire::raw_wire_bytes(n),
            );
            net.send(link, Dir::Fwd, step as u64, Payload::Size(hop), raw, net.clock(link))
                .expect("SimNet delivers every allreduce hop");
        }
        for link in 0..links {
            let (s, r) = (link / dp, link % dp);
            let dst = s * dp + (r + 1) % dp;
            let arrival =
                net.recv(link, Dir::Fwd, step as u64).expect("allreduce hop delivered").arrival;
            net.advance(dst, arrival);
        }
    }
    SimReport {
        makespan_s: net.makespan(),
        busy_s: pp.busy_s * dp as f64 + net.busy_time(),
        wire_sum_s: pp.wire_sum_s * dp as f64 + net.ledger().total_sim_time(),
        bytes: pp.bytes * dp as u64 + net.ledger().total_bytes(),
        raw_bytes: pp.raw_bytes * dp as u64 + net.ledger().total_uncompressed_bytes(),
        wire_elapsed_s: pp.wire_elapsed_s * dp as f64,
    }
}

/// Execute the schedule through any [`Transport`], gating each op on
/// the arrival of its input message. Messages are keyed by
/// `(boundary, mb)` so boundaries sharing a physical ring link (the
/// interleaved case) stay distinguishable while still contending for
/// the link's bandwidth and in-flight window.
pub fn simulate_transport(
    ops: &[Op],
    spec: &SimSpec,
    net: &mut dyn Transport,
) -> Result<SimReport, TransportError> {
    let (s_count, v, m_count) = (spec.n_stages, spec.v, spec.n_mb);
    let n_ms = s_count * v;
    // producer-side completion times per (model stage, mb)
    let mut fwd_end = vec![vec![0.0f64; m_count]; n_ms];
    let mut bwd_end = vec![vec![0.0f64; m_count]; n_ms];
    for op in ops {
        let (rank, mb) = (op.rank(), op.mb());
        let ms = op.model_stage(s_count);
        match op {
            Op::Fwd { .. } => {
                let ready = if ms == 0 {
                    0.0
                } else if s_count == 1 {
                    // same-rank chunk boundary: handoff is free
                    fwd_end[ms - 1][mb]
                } else {
                    let boundary = ms - 1;
                    let link = boundary % s_count;
                    let key = (boundary * m_count + mb) as u64;
                    net.send(
                        link,
                        Dir::Fwd,
                        key,
                        Payload::Size(spec.fwd_bytes[boundary]),
                        spec.raw_bytes[boundary],
                        fwd_end[boundary][mb],
                    )?;
                    net.recv(link, Dir::Fwd, key)?.arrival
                };
                let start = net.clock(rank).max(ready);
                let end = start + spec.fwd_op_s;
                net.advance(rank, end);
                fwd_end[ms][mb] = end;
            }
            Op::Bwd { .. } => {
                let ready = if ms + 1 == n_ms {
                    fwd_end[ms][mb]
                } else if s_count == 1 {
                    bwd_end[ms + 1][mb]
                } else {
                    let boundary = ms;
                    let link = boundary % s_count;
                    let key = (boundary * m_count + mb) as u64;
                    net.send(
                        link,
                        Dir::Bwd,
                        key,
                        Payload::Size(spec.bwd_bytes[boundary]),
                        spec.raw_bytes[boundary],
                        bwd_end[ms + 1][mb],
                    )?;
                    net.recv(link, Dir::Bwd, key)?.arrival
                };
                let start = net.clock(rank).max(ready);
                let end = start + spec.bwd_op_s + spec.recompute_s;
                net.advance(rank, end);
                bwd_end[ms][mb] = end;
            }
        }
    }
    Ok(SimReport {
        makespan_s: net.makespan(),
        busy_s: net.busy_time(),
        wire_sum_s: net.ledger().total_sim_time(),
        bytes: net.ledger().total_bytes(),
        raw_bytes: net.ledger().total_uncompressed_bytes(),
        wire_elapsed_s: net.wire_elapsed_s(),
    })
}

/// Per-direction wire bytes of one message under a compression spec
/// (what the trainer's links charge, computed without materializing).
pub fn spec_wire_bytes(spec: &crate::compression::Spec, n: usize) -> (usize, usize) {
    use crate::compression::{ops, wire, Feedback, Method};
    match spec.method {
        Method::None => (wire::raw_wire_bytes(n), wire::raw_wire_bytes(n)),
        Method::Quant { fw_bits, bw_bits } => {
            (wire::quant_wire_bytes(n, fw_bits), wire::quant_wire_bytes(n, bw_bits))
        }
        Method::TopK { frac, feedback, .. } => {
            let k = ops::budget(n, frac);
            let plain = wire::sparse_wire_bytes(n, k);
            match feedback {
                // receiver-side protocol: only the gap-coded delta frame
                // crosses the wire
                Feedback::Ef21 => {
                    let d = delta_frame_estimate(n, frac);
                    (d, d)
                }
                // activations ship deltas; gradients fall back to TopK
                Feedback::AqSgd => (delta_frame_estimate(n, frac), plain),
                _ => (plain, plain),
            }
        }
    }
}

/// Representative steady-state EF21/AQ-SGD delta-frame size for a
/// TopK-`frac` delta on an n-element link. Delta frames are
/// data-dependent, but their steady-state support equals the TopK
/// budget, so one deterministic synthetic delta measured through the
/// real codec is representative (and exactly reproducible).
pub fn delta_frame_estimate(n: usize, frac: f32) -> usize {
    use crate::compression::wire;
    let mut rng = crate::util::rng::Rng::new(0xef21);
    let mut x = vec![0.0f32; n];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let zeros = vec![0.0f32; n];
    let (msg, k) = crate::coordinator::feedback::delta_topk(&x, &zeros, frac);
    wire::delta_update_bytes(&msg, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{gpipe, interleaved, makespan, one_f_one_b, validate};
    use crate::util::prop::run_prop;

    /// op_time 64, integer byte counts, bandwidth 1 B/s: every quantity
    /// in both models is an exact small integer in f64.
    fn exact_spec(s: usize, v: usize, m: usize, bytes: usize, capacity: usize) -> SimSpec {
        let boundaries = pipeline::num_boundaries(s, v);
        SimSpec {
            n_stages: s,
            v,
            n_mb: m,
            fwd_op_s: 64.0,
            bwd_op_s: 64.0,
            recompute_s: 0.0,
            fwd_bytes: vec![bytes; boundaries],
            bwd_bytes: vec![bytes; boundaries],
            raw_bytes: vec![bytes; boundaries],
            model: WireModel { bandwidth_bytes_per_s: 1.0, latency_s: 0.0 },
            capacity,
            faults: None,
        }
    }

    #[test]
    fn prop_no_contention_matches_analytic_exactly() {
        // Zero latency, a single in-flight message per link, and wire
        // time <= op time: the event-driven makespan must equal the
        // analytic pipeline::makespan() bit for bit — on the flat
        // schedules and on the interleaved ring.
        run_prop("simnet == analytic makespan", 40, |g| {
            let s = g.usize(1, 6);
            let m = g.usize(1, 10);
            let bytes = g.usize(0, 64); // tx <= op_time: no contention
            for ops in [gpipe(s, m), one_f_one_b(s, m)] {
                let want = makespan(&ops, s, 1, m, 64.0, bytes as f64);
                let got = simulate(&ops, &exact_spec(s, 1, m, bytes, 1)).makespan_s;
                if got != want {
                    return Err(format!(
                        "s={s} m={m} bytes={bytes}: sim {got} != analytic {want}"
                    ));
                }
            }
            let v = g.usize(2, 3);
            let m = s * g.usize(1, 3);
            let ops = interleaved(s, v, m).map_err(|e| e.to_string())?;
            let want = makespan(&ops, s, v, m, 64.0, bytes as f64);
            let got = simulate(&ops, &exact_spec(s, v, m, bytes, 1)).makespan_s;
            if got != want {
                return Err(format!(
                    "interleaved s={s} v={v} m={m} bytes={bytes}: sim {got} != analytic {want}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_contention_strictly_exceeds_analytic() {
        // Wire time > op time: the producer emits faster than the link
        // drains, messages queue, and the measured makespan must be
        // strictly worse than the contention-blind analytic estimate.
        run_prop("simnet > analytic under contention", 40, |g| {
            let s = g.usize(2, 6);
            let m = g.usize(2, 10);
            let bytes = g.usize(80, 192); // tx in (op, 3*op]
            let capacity = *g.choose(&[1usize, 4]);
            let ops = gpipe(s, m);
            let want = makespan(&ops, s, 1, m, 64.0, bytes as f64);
            let got = simulate(&ops, &exact_spec(s, 1, m, bytes, capacity)).makespan_s;
            if got <= want {
                return Err(format!(
                    "s={s} m={m} bytes={bytes} cap={capacity}: sim {got} <= analytic {want}"
                ));
            }
            Ok(())
        });
    }

    /// The satellite pin at the traffic level: `Interleaved{v=1}` moves
    /// exactly the bytes of plain 1F1B through exactly the same links
    /// (op equality is pinned in `pipeline`; this closes the loop on
    /// makespan + bytes over the transport).
    #[test]
    fn interleaved_v1_matches_one_f_one_b_bytes_and_makespan() {
        for (s, m) in [(2, 3), (4, 8), (4, 16), (5, 7)] {
            let spec = exact_spec(s, 1, m, 48, 2);
            let flat = simulate(&one_f_one_b(s, m), &spec);
            let il = simulate(&interleaved(s, 1, m).unwrap(), &spec);
            assert_eq!(flat.bytes, il.bytes, "s={s} m={m}");
            assert_eq!(flat.raw_bytes, il.raw_bytes);
            assert_eq!(flat.makespan_s, il.makespan_s);
            assert_eq!(flat.busy_s, il.busy_s);
        }
    }

    #[test]
    fn interleaved_ring_ships_v_times_the_boundaries() {
        // v chunks per rank: 2*S*v - 2 messages per microbatch round
        // trip vs 2*(S-1) flat — same per-message size, ~v x bytes.
        let (s, m) = (4, 8);
        let flat = simulate(&one_f_one_b(s, m), &exact_spec(s, 1, m, 10, 4));
        let il = simulate(&interleaved(s, 2, m).unwrap(), &exact_spec(s, 2, m, 10, 4));
        let per_mb_flat = 2 * (s - 1);
        let per_mb_il = 2 * (2 * s - 1);
        assert_eq!(flat.bytes, (per_mb_flat * m * 10) as u64);
        assert_eq!(il.bytes, (per_mb_il * m * 10) as u64);
    }

    /// Per-boundary bytes: two boundaries sharing one ring link may
    /// carry differently-sized messages (the planner's heterogeneous
    /// specs) — the ledger charges exactly the per-boundary sizes, and
    /// shrinking only the *wrap* boundary's messages still shortens the
    /// makespan when that boundary gates the critical path.
    #[test]
    fn boundaries_sharing_a_link_carry_their_own_bytes() {
        let (s, v, m) = (2, 2, 4);
        let ops = interleaved(s, v, m).unwrap();
        let mut spec = exact_spec(s, v, m, 40, 4);
        assert_eq!(spec.boundaries(), 3);
        assert_eq!(spec.wire_links(), 2);
        let uniform = simulate(&ops, &spec);
        // boundaries 0 and 2 share physical link 0; boundary 1 wraps
        spec.fwd_bytes = vec![40, 8, 40];
        spec.bwd_bytes = vec![40, 8, 40];
        let het = simulate(&ops, &spec);
        let per_dir = (2 * 40 + 8) * m;
        assert_eq!(het.bytes, 2 * per_dir as u64);
        assert!(
            het.makespan_s < uniform.makespan_s,
            "{} !< {}",
            het.makespan_s,
            uniform.makespan_s
        );
        // raw ledger unchanged: compression, not topology, changed
        assert_eq!(het.raw_bytes, uniform.raw_bytes);
    }

    #[test]
    fn recompute_charges_gpipe_backward_phase() {
        let ops = gpipe(4, 8);
        let base = simulate(&ops, &exact_spec(4, 1, 8, 16, 4));
        let mut spec = exact_spec(4, 1, 8, 16, 4);
        spec.recompute_s = 64.0;
        let rc = simulate(&ops, &spec);
        assert!(rc.makespan_s > base.makespan_s);
        // same traffic either way
        assert_eq!(rc.bytes, base.bytes);
        assert!((rc.busy_s - base.busy_s).abs() < 1e-12);
    }

    #[test]
    fn latency_delays_makespan_but_not_busy_time() {
        let ops = one_f_one_b(4, 8);
        let mut spec = exact_spec(4, 1, 8, 32, 4);
        let quiet = simulate(&ops, &spec);
        spec.model.latency_s = 10.0;
        let laggy = simulate(&ops, &spec);
        assert!(laggy.makespan_s > quiet.makespan_s);
        assert!((laggy.busy_s - quiet.busy_s).abs() < 1e-12);
        assert!(laggy.wire_sum_s > quiet.wire_sum_s);
    }

    #[test]
    fn single_stage_has_no_traffic() {
        let ops = gpipe(1, 5);
        let r = simulate(&ops, &exact_spec(1, 1, 5, 1000, 1));
        assert_eq!(r.bytes, 0);
        assert!((r.makespan_s - 10.0 * 64.0).abs() < 1e-9);
        // all chunks on one rank: still no wire
        let ops = interleaved(1, 3, 5).unwrap();
        let r = simulate(&ops, &exact_spec(1, 3, 5, 1000, 1));
        assert_eq!(r.bytes, 0);
        assert!((r.makespan_s - 3.0 * 10.0 * 64.0).abs() < 1e-9);
    }

    #[test]
    fn schedules_agree_with_validation() {
        // the simulator consumes exactly the ops the validator accepts
        for (s, m) in [(2, 3), (4, 16)] {
            for ops in [gpipe(s, m), one_f_one_b(s, m)] {
                validate(&ops, s, 1, m).unwrap();
                let r = simulate(&ops, &exact_spec(s, 1, m, 8, 2));
                assert!(r.makespan_s.is_finite() && r.makespan_s > 0.0);
            }
        }
        for (s, v, m) in [(2, 2, 4), (4, 2, 16)] {
            let ops = interleaved(s, v, m).unwrap();
            validate(&ops, s, v, m).unwrap();
            let r = simulate(&ops, &exact_spec(s, v, m, 8, 2));
            assert!(r.makespan_s.is_finite() && r.makespan_s > 0.0);
        }
    }

    #[test]
    fn real_backend_ships_the_same_bytes_and_measures_wall_time() {
        // the same schedule over loopback TCP moves identical traffic
        // (ledger parity) and reports measured — not modelled — tx time
        let ops = gpipe(3, 4);
        let spec = exact_spec(3, 1, 4, 128, 4);
        let sim = simulate(&ops, &spec);
        let real = simulate_real(&ops, &spec, crate::netsim::Backend::Tcp).unwrap();
        assert_eq!(real.bytes, sim.bytes);
        assert_eq!(real.raw_bytes, sim.raw_bytes);
        assert!(real.wire_elapsed_s > 0.0, "no wall tx time measured");
        assert!(real.makespan_s > 0.0);
        assert_eq!(sim.wire_elapsed_s, 0.0);
    }

    #[test]
    fn real_backend_carries_the_interleaved_ring() {
        // v=2 over loopback: the wrap link (index = n_stages) exists and
        // the ring moves the same traffic the simulator charges
        let ops = interleaved(2, 2, 4).unwrap();
        let spec = exact_spec(2, 2, 4, 64, 4);
        assert_eq!(spec.wire_links(), 2);
        let sim = simulate(&ops, &spec);
        let real = simulate_real(&ops, &spec, crate::netsim::Backend::Tcp).unwrap();
        assert_eq!(real.bytes, sim.bytes);
        assert!(real.wire_elapsed_s > 0.0);
    }

    #[test]
    fn spec_wire_bytes_match_codec_formulas() {
        use crate::compression::{ops, wire, Spec};
        let n = 16_384;
        let (f, b) = spec_wire_bytes(&Spec::none(), n);
        assert_eq!((f, b), (wire::raw_wire_bytes(n), wire::raw_wire_bytes(n)));
        let (f, b) = spec_wire_bytes(&Spec::parse("quant:fw4-bw8").unwrap(), n);
        assert_eq!(f, wire::quant_wire_bytes(n, 4));
        assert_eq!(b, wire::quant_wire_bytes(n, 8));
        let (f, b) = spec_wire_bytes(&Spec::parse("topk:10").unwrap(), n);
        let k = ops::budget(n, 0.1);
        assert_eq!((f, b), (wire::sparse_wire_bytes(n, k), wire::sparse_wire_bytes(n, k)));
    }

    fn hybrid(dp: usize, grad_spec: &str) -> HybridSpec {
        HybridSpec {
            pp: exact_spec(4, 1, 8, 32, 4),
            dp,
            grad_elems: 4096,
            grad_spec: crate::compression::Spec::parse(grad_spec).unwrap(),
        }
    }

    #[test]
    fn hybrid_dp1_is_bit_identical_to_plain_pp() {
        let ops = one_f_one_b(4, 8);
        let spec = hybrid(1, "none");
        let pp = simulate(&ops, &spec.pp);
        let hy = simulate_hybrid(&ops, &spec);
        assert_eq!(hy.makespan_s.to_bits(), pp.makespan_s.to_bits());
        assert_eq!(hy.busy_s.to_bits(), pp.busy_s.to_bits());
        assert_eq!((hy.bytes, hy.raw_bytes), (pp.bytes, pp.raw_bytes));
    }

    #[test]
    fn hybrid_ring_charges_allreduce_traffic_after_the_pipeline() {
        let ops = one_f_one_b(4, 8);
        let spec = hybrid(4, "none");
        let pp = simulate(&ops, &spec.pp);
        let hy = simulate_hybrid(&ops, &spec);
        // dp pipelines' traffic plus a non-empty gradient exchange
        assert!(hy.bytes > pp.bytes * 4, "{} !> {}", hy.bytes, pp.bytes * 4);
        assert!(hy.makespan_s > pp.makespan_s);
        assert!(hy.busy_s > pp.busy_s * 4.0);
        // every ring step moves ~one full vector (dp segments of 1/dp
        // each): stages * 2(dp-1) steps bound the exchange
        let ar_bytes = hy.bytes - pp.bytes * 4;
        let vector = crate::compression::wire::raw_wire_bytes(spec.grad_elems) as u64;
        let bound = 4 * 2 * (4 - 1) * (vector + 4 * 64);
        assert!(ar_bytes <= bound, "{ar_bytes} !<= {bound}");
    }

    #[test]
    fn compressed_allreduce_beats_raw_gradients_at_low_bandwidth() {
        let ops = one_f_one_b(4, 8);
        let raw = simulate_hybrid(&ops, &hybrid(8, "none"));
        let ef21 = simulate_hybrid(&ops, &hybrid(8, "ef21+topk:10"));
        let quant = simulate_hybrid(&ops, &hybrid(8, "quant:fw8-bw6"));
        assert!(ef21.bytes < raw.bytes);
        assert!(quant.bytes < raw.bytes);
        assert!(ef21.makespan_s < raw.makespan_s, "{} !< {}", ef21.makespan_s, raw.makespan_s);
        // the raw ledger is compression-invariant
        assert_eq!(ef21.raw_bytes, raw.raw_bytes);
        assert_eq!(quant.raw_bytes, raw.raw_bytes);
    }

    #[test]
    fn smoke_512_ranks_through_the_event_core() {
        // 8 pipeline stages x 64 replicas = 512 simulated ranks; the
        // keyed-mailbox event core carries 2*(dp-1) ring steps over
        // 512 links without a linear-scan blowup.
        let ops = gpipe(8, 8);
        let spec = HybridSpec {
            pp: exact_spec(8, 1, 8, 32, 4),
            dp: 64,
            grad_elems: 16_384,
            grad_spec: crate::compression::Spec::parse("ef21+topk:10").unwrap(),
        };
        assert_eq!(spec.ranks(), 512);
        let r = simulate_hybrid(&ops, &spec);
        assert!(r.makespan_s.is_finite() && r.makespan_s > 0.0);
        let pp = simulate(&ops, &spec.pp);
        assert!(r.bytes > pp.bytes * 64);
        assert!(r.makespan_s > pp.makespan_s);
    }

    #[test]
    fn allreduce_hop_bytes_wraps_the_gradient_codec() {
        use crate::compression::{wire, Spec};
        let n = 2048;
        assert_eq!(
            allreduce_hop_bytes(&Spec::none(), n),
            wire::allreduce_wire_bytes(wire::raw_wire_bytes(n))
        );
        // gradient direction: quant picks bw bits
        assert_eq!(
            allreduce_hop_bytes(&Spec::parse("quant:fw4-bw8").unwrap(), n),
            wire::allreduce_wire_bytes(wire::quant_wire_bytes(n, 8))
        );
        assert!(allreduce_hop_bytes(&Spec::parse("ef21+topk:10").unwrap(), n)
            < allreduce_hop_bytes(&Spec::none(), n));
    }

    #[test]
    fn ef_delta_accounting_beats_plain_sparse() {
        use crate::compression::{ops, wire, Spec};
        let n = 16_384;
        let plain = wire::sparse_wire_bytes(n, ops::budget(n, 0.1));
        let (f, b) = spec_wire_bytes(&Spec::parse("ef21+topk:10").unwrap(), n);
        assert_eq!(f, b, "EF21 runs the delta protocol in both directions");
        assert!(f < plain, "ef21 frame {f} !< plain sparse {plain}");
        assert_eq!(f, delta_frame_estimate(n, 0.1), "estimate is deterministic");
        // AQ-SGD: deltas forward, plain TopK backward
        let (f, b) = spec_wire_bytes(&Spec::parse("aqsgd+topk:10").unwrap(), n);
        assert!(f < plain && b == plain);
    }
}
