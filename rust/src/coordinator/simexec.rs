//! Execute a pipeline schedule through the [`Transport`] and measure
//! its makespan — the successor of the analytic [`pipeline::makespan`]
//! estimate.
//!
//! The executor walks the schedule in order, keeping one clock per
//! stage. A forward op on stage `s > 0` starts no earlier than the
//! arrival of its input activations (sent when stage `s - 1` finished
//! producing them); a backward op on stage `s < S - 1` is gated the
//! same way on the gradient message. On the default [`SimNet`] backend
//! messages contend for link bandwidth and respect the bounded
//! in-flight window, so — unlike the analytic model — bursts of traffic
//! (GPipe's all-forward phase) are charged their queueing delay. On the
//! real backends ([`simulate_real`]) frames of the scheduled sizes
//! actually cross loopback kernel sockets and the report's busy/elapsed
//! columns are measured wall-clock I/O time.
//!
//! With zero latency and no contention the simulated model agrees with
//! the analytic one *exactly*; the property tests below pin that
//! equivalence, which is the correctness anchor for everything the
//! simulator reports.

use std::time::Duration;

use crate::coordinator::pipeline::Op;
use crate::netsim::{
    Backend, Dir, Payload, RealTransport, SimNet, Transport, TransportError, WireModel,
};

/// Static description of one simulated pipeline run.
#[derive(Clone, Debug)]
pub struct SimSpec {
    pub n_stages: usize,
    pub n_mb: usize,
    /// Compute cost of one forward op.
    pub fwd_op_s: f64,
    /// Compute cost of one backward op.
    pub bwd_op_s: f64,
    /// Extra forward recomputation charged per backward op (GPipe's
    /// rematerialization: it discards activations it cannot afford to
    /// stash for all `n_mb` microbatches and recomputes them in the
    /// backward phase; 1F1B's depth-bounded stash avoids this).
    pub recompute_s: f64,
    /// Payload bytes per forward (activation) message, per link.
    pub fwd_bytes: Vec<usize>,
    /// Payload bytes per backward (gradient) message, per link.
    pub bwd_bytes: Vec<usize>,
    /// Uncompressed payload bytes per message, per link (ledger).
    pub raw_bytes: Vec<usize>,
    pub model: WireModel,
    /// Bounded in-flight window per link direction.
    pub capacity: usize,
}

/// Measured outcome of one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct SimReport {
    /// End-to-end time of the schedule (max worker clock; wall time of
    /// the last wire event on real backends).
    pub makespan_s: f64,
    /// Bandwidth-occupancy seconds summed over channels (no latency);
    /// measured socket-write seconds on real backends.
    pub busy_s: f64,
    /// Sum of per-message wire times (latency + serialization) — the
    /// pre-simulator accounting metric, kept for comparison.
    pub wire_sum_s: f64,
    pub bytes: u64,
    pub raw_bytes: u64,
    /// Measured wall-clock tx time (0 on the simulator).
    pub wire_elapsed_s: f64,
}

/// Run `ops` through a fresh `SimNet` described by `spec`.
pub fn simulate(ops: &[Op], spec: &SimSpec) -> SimReport {
    let mut net =
        SimNet::with_capacity(spec.n_stages.saturating_sub(1), spec.model, spec.capacity);
    simulate_transport(ops, spec, &mut net).expect("SimNet delivers every scheduled message")
}

/// Run `ops` over a real loopback transport (tcp/uds): frames of the
/// scheduled sizes actually cross kernel sockets.
pub fn simulate_real(
    ops: &[Op],
    spec: &SimSpec,
    backend: Backend,
) -> Result<SimReport, TransportError> {
    let mut net = RealTransport::loopback(
        spec.n_stages.saturating_sub(1),
        backend,
        spec.model,
        Duration::from_secs(20),
    )?;
    let report = simulate_transport(ops, spec, &mut net)?;
    net.shutdown()?;
    Ok(report)
}

/// Execute the schedule through any [`Transport`], gating each op on
/// the arrival of its input message.
pub fn simulate_transport(
    ops: &[Op],
    spec: &SimSpec,
    net: &mut dyn Transport,
) -> Result<SimReport, TransportError> {
    let (s_count, m_count) = (spec.n_stages, spec.n_mb);
    // producer-side completion times per (stage, mb)
    let mut fwd_end = vec![vec![0.0f64; m_count]; s_count];
    let mut bwd_end = vec![vec![0.0f64; m_count]; s_count];
    for op in ops {
        match *op {
            Op::Fwd { stage, mb } => {
                let ready = if stage == 0 {
                    0.0
                } else {
                    let key = mb as u64;
                    let link = stage - 1;
                    net.send(
                        link,
                        Dir::Fwd,
                        key,
                        Payload::Size(spec.fwd_bytes[link]),
                        spec.raw_bytes[link],
                        fwd_end[link][mb],
                    )?;
                    net.recv(link, Dir::Fwd, key)?.arrival
                };
                let start = net.clock(stage).max(ready);
                let end = start + spec.fwd_op_s;
                net.advance(stage, end);
                fwd_end[stage][mb] = end;
            }
            Op::Bwd { stage, mb } => {
                let ready = if stage + 1 == s_count {
                    fwd_end[stage][mb]
                } else {
                    let key = mb as u64;
                    let link = stage;
                    net.send(
                        link,
                        Dir::Bwd,
                        key,
                        Payload::Size(spec.bwd_bytes[link]),
                        spec.raw_bytes[link],
                        bwd_end[stage + 1][mb],
                    )?;
                    net.recv(link, Dir::Bwd, key)?.arrival
                };
                let start = net.clock(stage).max(ready);
                let end = start + spec.bwd_op_s + spec.recompute_s;
                net.advance(stage, end);
                bwd_end[stage][mb] = end;
            }
        }
    }
    Ok(SimReport {
        makespan_s: net.makespan(),
        busy_s: net.busy_time(),
        wire_sum_s: net.ledger().total_sim_time(),
        bytes: net.ledger().total_bytes(),
        raw_bytes: net.ledger().total_uncompressed_bytes(),
        wire_elapsed_s: net.wire_elapsed_s(),
    })
}

/// Per-direction wire bytes of one message under a compression spec
/// (what the trainer's links charge, computed without materializing).
pub fn spec_wire_bytes(spec: &crate::compression::Spec, n: usize) -> (usize, usize) {
    use crate::compression::{ops, wire, Feedback, Method};
    match spec.method {
        Method::None => (wire::raw_wire_bytes(n), wire::raw_wire_bytes(n)),
        Method::Quant { fw_bits, bw_bits } => {
            (wire::quant_wire_bytes(n, fw_bits), wire::quant_wire_bytes(n, bw_bits))
        }
        Method::TopK { frac, feedback, .. } => {
            let k = ops::budget(n, frac);
            let plain = wire::sparse_wire_bytes(n, k);
            match feedback {
                // receiver-side protocol: only the gap-coded delta frame
                // crosses the wire
                Feedback::Ef21 => {
                    let d = delta_frame_estimate(n, frac);
                    (d, d)
                }
                // activations ship deltas; gradients fall back to TopK
                Feedback::AqSgd => (delta_frame_estimate(n, frac), plain),
                _ => (plain, plain),
            }
        }
    }
}

/// Representative steady-state EF21/AQ-SGD delta-frame size for a
/// TopK-`frac` delta on an n-element link. Delta frames are
/// data-dependent, but their steady-state support equals the TopK
/// budget, so one deterministic synthetic delta measured through the
/// real codec is representative (and exactly reproducible).
pub fn delta_frame_estimate(n: usize, frac: f32) -> usize {
    use crate::compression::wire;
    let mut rng = crate::util::rng::Rng::new(0xef21);
    let mut x = vec![0.0f32; n];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let zeros = vec![0.0f32; n];
    let (msg, k) = crate::coordinator::feedback::delta_topk(&x, &zeros, frac);
    wire::delta_update_bytes(&msg, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{self, gpipe, makespan, one_f_one_b};
    use crate::util::prop::run_prop;

    /// op_time 64, integer byte counts, bandwidth 1 B/s: every quantity
    /// in both models is an exact small integer in f64.
    fn exact_spec(s: usize, m: usize, bytes: usize, capacity: usize) -> SimSpec {
        SimSpec {
            n_stages: s,
            n_mb: m,
            fwd_op_s: 64.0,
            bwd_op_s: 64.0,
            recompute_s: 0.0,
            fwd_bytes: vec![bytes; s.saturating_sub(1)],
            bwd_bytes: vec![bytes; s.saturating_sub(1)],
            raw_bytes: vec![bytes; s.saturating_sub(1)],
            model: WireModel { bandwidth_bytes_per_s: 1.0, latency_s: 0.0 },
            capacity,
        }
    }

    #[test]
    fn prop_no_contention_matches_analytic_exactly() {
        // Zero latency, a single in-flight message per link, and wire
        // time <= op time: the event-driven makespan must equal the
        // analytic pipeline::makespan() bit for bit.
        run_prop("simnet == analytic makespan", 40, |g| {
            let s = g.usize(1, 6);
            let m = g.usize(1, 10);
            let bytes = g.usize(0, 64); // tx <= op_time: no contention
            for ops in [gpipe(s, m), one_f_one_b(s, m)] {
                let want = makespan(&ops, s, m, 64.0, bytes as f64);
                let got = simulate(&ops, &exact_spec(s, m, bytes, 1)).makespan_s;
                if got != want {
                    return Err(format!(
                        "s={s} m={m} bytes={bytes}: sim {got} != analytic {want}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_contention_strictly_exceeds_analytic() {
        // Wire time > op time: the producer emits faster than the link
        // drains, messages queue, and the measured makespan must be
        // strictly worse than the contention-blind analytic estimate.
        run_prop("simnet > analytic under contention", 40, |g| {
            let s = g.usize(2, 6);
            let m = g.usize(2, 10);
            let bytes = g.usize(80, 192); // tx in (op, 3*op]
            let capacity = *g.choose(&[1usize, 4]);
            let ops = gpipe(s, m);
            let want = makespan(&ops, s, m, 64.0, bytes as f64);
            let got = simulate(&ops, &exact_spec(s, m, bytes, capacity)).makespan_s;
            if got <= want {
                return Err(format!(
                    "s={s} m={m} bytes={bytes} cap={capacity}: sim {got} <= analytic {want}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn recompute_charges_gpipe_backward_phase() {
        let ops = gpipe(4, 8);
        let base = simulate(&ops, &exact_spec(4, 8, 16, 4));
        let mut spec = exact_spec(4, 8, 16, 4);
        spec.recompute_s = 64.0;
        let rc = simulate(&ops, &spec);
        assert!(rc.makespan_s > base.makespan_s);
        // same traffic either way
        assert_eq!(rc.bytes, base.bytes);
        assert!((rc.busy_s - base.busy_s).abs() < 1e-12);
    }

    #[test]
    fn latency_delays_makespan_but_not_busy_time() {
        let ops = one_f_one_b(4, 8);
        let mut spec = exact_spec(4, 8, 32, 4);
        let quiet = simulate(&ops, &spec);
        spec.model.latency_s = 10.0;
        let laggy = simulate(&ops, &spec);
        assert!(laggy.makespan_s > quiet.makespan_s);
        assert!((laggy.busy_s - quiet.busy_s).abs() < 1e-12);
        assert!(laggy.wire_sum_s > quiet.wire_sum_s);
    }

    #[test]
    fn single_stage_has_no_traffic() {
        let ops = gpipe(1, 5);
        let r = simulate(&ops, &exact_spec(1, 5, 1000, 1));
        assert_eq!(r.bytes, 0);
        assert!((r.makespan_s - 10.0 * 64.0).abs() < 1e-9);
    }

    #[test]
    fn schedules_agree_with_validation() {
        // the simulator consumes exactly the ops the validator accepts
        for (s, m) in [(2, 3), (4, 16)] {
            for ops in [gpipe(s, m), one_f_one_b(s, m)] {
                pipeline::validate(&ops, s, m).unwrap();
                let r = simulate(&ops, &exact_spec(s, m, 8, 2));
                assert!(r.makespan_s.is_finite() && r.makespan_s > 0.0);
            }
        }
    }

    #[test]
    fn real_backend_ships_the_same_bytes_and_measures_wall_time() {
        // the same schedule over loopback TCP moves identical traffic
        // (ledger parity) and reports measured — not modelled — tx time
        let ops = gpipe(3, 4);
        let spec = exact_spec(3, 4, 128, 4);
        let sim = simulate(&ops, &spec);
        let real = simulate_real(&ops, &spec, crate::netsim::Backend::Tcp).unwrap();
        assert_eq!(real.bytes, sim.bytes);
        assert_eq!(real.raw_bytes, sim.raw_bytes);
        assert!(real.wire_elapsed_s > 0.0, "no wall tx time measured");
        assert!(real.makespan_s > 0.0);
        assert_eq!(sim.wire_elapsed_s, 0.0);
    }

    #[test]
    fn spec_wire_bytes_match_codec_formulas() {
        use crate::compression::{ops, wire, Spec};
        let n = 16_384;
        let (f, b) = spec_wire_bytes(&Spec::none(), n);
        assert_eq!((f, b), (wire::raw_wire_bytes(n), wire::raw_wire_bytes(n)));
        let (f, b) = spec_wire_bytes(&Spec::parse("quant:fw4-bw8").unwrap(), n);
        assert_eq!(f, wire::quant_wire_bytes(n, 4));
        assert_eq!(b, wire::quant_wire_bytes(n, 8));
        let (f, b) = spec_wire_bytes(&Spec::parse("topk:10").unwrap(), n);
        let k = ops::budget(n, 0.1);
        assert_eq!((f, b), (wire::sparse_wire_bytes(n, k), wire::sparse_wire_bytes(n, k)));
    }

    #[test]
    fn ef_delta_accounting_beats_plain_sparse() {
        use crate::compression::{ops, wire, Spec};
        let n = 16_384;
        let plain = wire::sparse_wire_bytes(n, ops::budget(n, 0.1));
        let (f, b) = spec_wire_bytes(&Spec::parse("ef21+topk:10").unwrap(), n);
        assert_eq!(f, b, "EF21 runs the delta protocol in both directions");
        assert!(f < plain, "ef21 frame {f} !< plain sparse {plain}");
        assert_eq!(f, delta_frame_estimate(n, 0.1), "estimate is deterministic");
        // AQ-SGD: deltas forward, plain TopK backward
        let (f, b) = spec_wire_bytes(&Spec::parse("aqsgd+topk:10").unwrap(), n);
        assert!(f < plain && b == plain);
    }
}
