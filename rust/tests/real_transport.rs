//! Real-socket transport integration tests.
//!
//! The first half needs nothing but loopback sockets: framing
//! roundtrips, keyed out-of-order delivery, typed timeout/disconnect
//! errors, the multi-process rendezvous handshake (two endpoint
//! transports in two threads), and the sim/real parity property — the
//! same synthetic schedule over `SimNet` and over real TCP delivers the
//! same per-mailbox message ordering, byte counts, and payload digests.
//!
//! The second half (artifacts-gated, like `tests/integration.rs`)
//! asserts the refactor's core guarantee: training with `backend = uds`
//! — every compressed activation/gradient crossing a real kernel socket
//! and the consumer using the *decoded* frames — produces bit-identical
//! trained parameters and identical per-link byte counts to the
//! `SimNet` run.

use std::time::Duration;

use mpcomp::compression::Spec;
use mpcomp::config::{CompressImpl, Schedule, TrainConfig, WireOpts};
use mpcomp::coordinator::worker::{self, WorkerOpts};
use mpcomp::coordinator::Trainer;
use mpcomp::netsim::{
    Backend, Dir, Payload, RealTransport, Transport, TransportError, WireModel,
};
use mpcomp::runtime::Runtime;
use mpcomp::tensor::Tensor;
use mpcomp::util::prop::run_prop;

fn loopback(backend: Backend, links: usize) -> RealTransport {
    RealTransport::loopback(links, backend, WireModel::datacenter(), Duration::from_secs(5))
        .expect("loopback transport")
}

fn roundtrip(backend: Backend) {
    let mut net = loopback(backend, 2);
    assert_eq!(net.backend(), backend);
    assert!(net.wants_payload());
    assert_eq!(net.num_links(), 2);
    let msg = vec![1u8, 2, 3, 4, 5];
    net.send(0, Dir::Fwd, 7, Payload::Bytes(&msg), 100, 0.0).unwrap();
    net.send(1, Dir::Bwd, 9, Payload::Size(8), 64, 0.0).unwrap();
    let f = net.recv(0, Dir::Fwd, 7).unwrap();
    assert_eq!((f.key, f.bytes), (7, 5));
    assert_eq!(f.payload.as_deref(), Some(&msg[..]));
    assert!(f.arrival > 0.0);
    let g = net.recv(1, Dir::Bwd, 9).unwrap();
    assert_eq!(g.bytes, 8);
    assert_eq!(g.payload.as_deref(), Some(&[0u8; 8][..]), "Size payloads ship zero-filled");
    // the ledger charged exactly the frame payloads
    assert_eq!(net.ledger().total_bytes(), 13);
    assert_eq!(net.ledger().total_uncompressed_bytes(), 164);
    assert!(net.wire_elapsed_s() > 0.0, "tx time must be measured");
    assert!(net.makespan() > 0.0);
    net.shutdown().unwrap();
}

#[test]
fn loopback_roundtrip_tcp() {
    roundtrip(Backend::Tcp);
}

#[test]
fn loopback_roundtrip_uds() {
    roundtrip(Backend::Uds);
}

#[test]
fn keyed_mailbox_delivers_out_of_order() {
    let mut net = loopback(Backend::Uds, 1);
    for key in 0..3u64 {
        net.send(0, Dir::Fwd, key, Payload::Bytes(&[key as u8; 4]), 4, 0.0).unwrap();
    }
    // ask for the last one first: the mailbox is keyed, not FIFO-only
    let f2 = net.recv(0, Dir::Fwd, 2).unwrap();
    assert_eq!(f2.payload.as_deref(), Some(&[2u8; 4][..]));
    let f0 = net.recv(0, Dir::Fwd, 0).unwrap();
    assert_eq!(f0.payload.as_deref(), Some(&[0u8; 4][..]));
    assert!(net.recv(0, Dir::Fwd, 1).is_ok());
    net.shutdown().unwrap();
}

#[test]
fn recv_timeout_is_typed() {
    let mut net = RealTransport::loopback(
        1,
        Backend::Uds,
        WireModel::datacenter(),
        Duration::from_millis(50),
    )
    .unwrap();
    match net.recv(0, Dir::Fwd, 42) {
        Err(TransportError::Timeout { link: 0, dir: Dir::Fwd, key: 42 }) => {}
        other => panic!("want typed timeout, got {other:?}"),
    }
}

#[test]
fn disconnect_is_typed() {
    let mut net = loopback(Backend::Uds, 1);
    net.shutdown().unwrap();
    match net.recv(0, Dir::Fwd, 1) {
        Err(TransportError::Disconnected { link: 0, .. }) => {}
        other => panic!("want typed disconnect, got {other:?}"),
    }
}

#[test]
fn bad_link_is_typed() {
    let mut net = loopback(Backend::Tcp, 1);
    match net.send(5, Dir::Fwd, 0, Payload::Size(1), 1, 0.0) {
        Err(TransportError::NoSuchLink { link: 5 }) => {}
        other => panic!("want NoSuchLink, got {other:?}"),
    }
    match net.recv(9, Dir::Bwd, 0) {
        Err(TransportError::NoSuchLink { link: 9 }) => {}
        other => panic!("want NoSuchLink, got {other:?}"),
    }
    net.shutdown().unwrap();
}

// ---------------------------------------------------------------------------
// sim/real parity on synthetic schedules (the worker path)
// ---------------------------------------------------------------------------

fn worker_opts(stages: usize, mb: usize, link_elems: usize, mode: &str, seed: u64) -> WorkerOpts {
    WorkerOpts {
        stages,
        mb,
        link_elems,
        schedule: Schedule::GPipe,
        spec: Spec::parse(mode).unwrap(),
        plan: None,
        seed,
        wire: WireOpts {
            profile: "datacenter".into(),
            recv_timeout_s: 10.0,
            ..WireOpts::default()
        },
        steps: 1,
        dp: 1,
    }
}

#[test]
fn prop_real_backend_matches_sim_mailboxes() {
    // For the same schedule, the TCP loopback transport must deliver
    // the same per-(link, dir) mailbox ordering, byte counts, and
    // payload digests as the SimNet reference — error-feedback specs
    // included (the delta protocol runs its receiver mirrors on both).
    run_prop("tcp mailboxes == sim mailboxes", 6, |g| {
        let stages = g.usize(2, 3);
        let mb = g.usize(1, 4);
        let elems = g.usize(8, 200);
        let mode =
            *g.choose(&["none", "topk:10", "quant:fw4-bw6", "ef21+topk:10", "aqsgd+topk:30"]);
        let mut opts = worker_opts(stages, mb, elems, mode, g.usize(0, 1 << 20) as u64);
        opts.steps = g.usize(1, 2);
        if g.bool() {
            opts.schedule = Schedule::OneFOneB;
        }
        let reference = worker::run_reference(&opts).map_err(|e| e.to_string())?;
        let real = worker::run_loopback(&opts, Backend::Tcp).map_err(|e| e.to_string())?;
        worker::check(&reference, &[real]).map_err(|e| e.to_string())
    });
}

#[test]
fn uds_loopback_matches_sim_reference() {
    let opts = worker_opts(2, 4, 512, "topk:10", 3);
    let reference = worker::run_reference(&opts).unwrap();
    let real = worker::run_loopback(&opts, Backend::Uds).unwrap();
    assert!(real.wire_elapsed_s > 0.0);
    worker::check(&reference, &[real]).unwrap();
}

#[test]
fn endpoint_rendezvous_two_threads_uds() {
    // The exact path the CI loopback job runs across two OS processes:
    // two endpoint transports rendezvous over a socket directory,
    // exchange the schedule's compressed messages, and each rank's
    // summary must be bit-identical to the single-process reference.
    let opts = worker_opts(2, 3, 128, "topk:10", 5);
    let dir = std::env::temp_dir().join(format!("mpcomp-rv-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let addr = dir.to_str().unwrap().to_string();

    let o0 = opts.clone();
    let a0 = addr.clone();
    let h0 = std::thread::spawn(move || worker::run_rank(&o0, 0, Backend::Uds, &a0));
    let o1 = opts.clone();
    let h1 = std::thread::spawn(move || worker::run_rank(&o1, 1, Backend::Uds, &addr));
    let s0 = h0.join().unwrap().unwrap();
    let s1 = h1.join().unwrap().unwrap();

    // rank 0 received all gradients, rank 1 all activations
    assert_eq!(s0.received(), 3);
    assert_eq!(s1.received(), 3);
    let reference = worker::run_reference(&opts).unwrap();
    worker::check(&reference, &[s0, s1]).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn interleaved_loopback_matches_sim_reference() {
    // v=2 over real UDS sockets: the ring (wrap link included) delivers
    // the same per-mailbox logs as the SimNet reference, per-channel
    // feedback mirrors included.
    for mode in ["topk:10", "ef21+topk:10"] {
        let mut opts = worker_opts(2, 4, 256, mode, 11);
        opts.schedule = Schedule::Interleaved { v: 2 };
        opts.steps = 2;
        let reference = worker::run_reference(&opts).unwrap();
        let real = worker::run_loopback(&opts, Backend::Uds).unwrap();
        worker::check(&reference, &[real]).unwrap_or_else(|e| panic!("{mode}: {e}"));
    }
}

#[test]
fn interleaved_endpoint_rendezvous_two_threads_uds() {
    // Two ranks, two chunks each: the ring rendezvous (every rank
    // listens AND connects — the wrap link carries rank 1's chunk-0
    // output back to rank 0's chunk 1) must come up from two threads
    // and match the single-process reference bit for bit.
    let mut opts = worker_opts(2, 4, 128, "topk:10", 13);
    opts.schedule = Schedule::Interleaved { v: 2 };
    let dir = std::env::temp_dir().join(format!("mpcomp-rv-il-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let addr = dir.to_str().unwrap().to_string();

    let o0 = opts.clone();
    let a0 = addr.clone();
    let h0 = std::thread::spawn(move || worker::run_rank(&o0, 0, Backend::Uds, &a0));
    let o1 = opts.clone();
    let h1 = std::thread::spawn(move || worker::run_rank(&o1, 1, Backend::Uds, &addr));
    let s0 = h0.join().unwrap().unwrap();
    let s1 = h1.join().unwrap().unwrap();

    // 3 boundaries x 4 mb per direction, split by consumer rank:
    // rank 0 consumes the wrap fwd (4) + both bwd boundaries (8)
    assert_eq!(s0.received(), 12);
    assert_eq!(s1.received(), 12);
    let reference = worker::run_reference(&opts).unwrap();
    worker::check(&reference, &[s0, s1]).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance pin (plan negotiation): two ranks whose hellos carry
/// different plan digests must fail with the typed
/// `TransportError::PlanMismatch` on BOTH real backends — both sides of
/// the link see the typed error (the acceptor replies before checking,
/// so the connector gets a digest too, not a dead socket), and since the
/// handshake precedes every frame, no feedback mirror is ever touched.
fn digest_mismatch_is_typed(backend: Backend, addr: &str) {
    // rank 0 ships topk:10, rank 1 believes the run is ef21+topk:10:
    // their uniform-plan digests differ
    let o0 = worker_opts(2, 2, 64, "topk:10", 1);
    let o1 = worker_opts(2, 2, 64, "ef21+topk:10", 1);
    let a0 = addr.to_string();
    let a1 = addr.to_string();
    let h0 = std::thread::spawn(move || worker::run_rank(&o0, 0, backend, &a0));
    let h1 = std::thread::spawn(move || worker::run_rank(&o1, 1, backend, &a1));
    let r0 = h0.join().unwrap();
    let r1 = h1.join().unwrap();
    for (rank, r) in [(0, r0), (1, r1)] {
        let err = r.expect_err("mismatched digests must fail the handshake");
        let te = err
            .downcast_ref::<TransportError>()
            .unwrap_or_else(|| panic!("rank {rank}: untyped error {err:#}"));
        assert!(
            matches!(te, TransportError::PlanMismatch { link: 0, ours, theirs } if ours != theirs),
            "rank {rank}: {te:?}"
        );
    }
}

#[test]
fn plan_digest_mismatch_typed_error_uds() {
    let dir = std::env::temp_dir().join(format!("mpcomp-rv-dig-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    digest_mismatch_is_typed(Backend::Uds, dir.to_str().unwrap());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn plan_digest_mismatch_typed_error_tcp() {
    digest_mismatch_is_typed(Backend::Tcp, "127.0.0.1:47641");
}

/// Matching plans rendezvous fine — including a *heterogeneous* plan
/// file loaded by both ranks (the CI loopback lane's shape), whose
/// per-channel frames still match the single-process SimNet reference.
#[test]
fn negotiated_heterogeneous_plan_two_threads_uds() {
    use mpcomp::planner::{BoundaryPlan, Plan};
    let mut opts = worker_opts(2, 4, 256, "none", 17);
    opts.schedule = Schedule::Interleaved { v: 2 };
    opts.steps = 2;
    let plan = Plan {
        n_ranks: 2,
        v: 2,
        queue_cap: 4,
        boundaries: vec![
            BoundaryPlan {
                fwd: Spec::parse("topk:10").unwrap(),
                bwd: Spec::parse("quant:fw8-bw8").unwrap(),
            },
            BoundaryPlan {
                fwd: Spec::parse("ef21+topk:10").unwrap(),
                bwd: Spec::parse("topk:30").unwrap(),
            },
            BoundaryPlan {
                fwd: Spec::parse("quant:fw4-bw8").unwrap(),
                bwd: Spec::none(),
            },
        ],
    };
    opts.plan = Some(plan);
    let dir = std::env::temp_dir().join(format!("mpcomp-rv-plan-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let addr = dir.to_str().unwrap().to_string();
    let o0 = opts.clone();
    let a0 = addr.clone();
    let h0 = std::thread::spawn(move || worker::run_rank(&o0, 0, Backend::Uds, &a0));
    let o1 = opts.clone();
    let h1 = std::thread::spawn(move || worker::run_rank(&o1, 1, Backend::Uds, &addr));
    let s0 = h0.join().unwrap().unwrap();
    let s1 = h1.join().unwrap().unwrap();
    let reference = worker::run_reference(&opts).unwrap();
    worker::check(&reference, &[s0, s1]).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn endpoint_rendezvous_two_threads_tcp() {
    let opts = worker_opts(2, 2, 64, "none", 9);
    // fixed high port; the link offset keeps runs on port + 0 only here
    let addr = "127.0.0.1:47613".to_string();
    let o0 = opts.clone();
    let a0 = addr.clone();
    let h0 = std::thread::spawn(move || worker::run_rank(&o0, 0, Backend::Tcp, &a0));
    let o1 = opts.clone();
    let h1 = std::thread::spawn(move || worker::run_rank(&o1, 1, Backend::Tcp, &addr));
    let s0 = h0.join().unwrap().unwrap();
    let s1 = h1.join().unwrap().unwrap();
    let reference = worker::run_reference(&opts).unwrap();
    worker::check(&reference, &[s0, s1]).unwrap();
}

// ---------------------------------------------------------------------------
// trainer-level (artifacts-gated): real backend == sim backend, bit for bit
// ---------------------------------------------------------------------------

fn artifacts_ready() -> bool {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ok = std::path::Path::new(dir).join("manifest.json").exists();
    if !ok {
        eprintln!("artifacts not built; skipping integration test");
    }
    ok
}

fn tiny_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::defaults("cnn16");
    cfg.artifacts_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into();
    cfg.results_dir = std::env::temp_dir().join("mpcomp_realtest").to_str().unwrap().into();
    cfg.train_size = 200;
    cfg.test_size = 100;
    cfg.epochs = 1;
    cfg.lr0 = 0.05;
    cfg.compress_impl = CompressImpl::Native;
    cfg.sim_op_time = Some(0.020);
    cfg
}

fn run_once(cfg: TrainConfig) -> (Vec<Vec<Tensor>>, u64, f64) {
    let rt = Runtime::from_dir(&cfg.artifacts_dir).expect("loading artifacts");
    let mut trainer = Trainer::new(rt, cfg).unwrap();
    let m = trainer.run().unwrap();
    (trainer.stage_params(), m.wire_bytes, m.wire_elapsed_s)
}

#[test]
fn training_over_uds_is_bit_identical_to_sim() {
    // The acceptance guarantee: a 2+-stage schedule trained over the
    // real backend (every message through kernel sockets, consumers
    // using the decoded frames) yields bit-identical parameters and
    // identical per-link byte counts to the SimNet run.
    if !artifacts_ready() {
        return;
    }
    for mode in ["none", "topk:10", "quant:fw4-bw6"] {
        let mut base = tiny_cfg();
        base.spec = Spec::parse(mode).unwrap();
        let (p_sim, bytes_sim, elapsed_sim) = run_once(base.clone());
        let mut real = base.clone();
        real.backend = "uds".into();
        let (p_uds, bytes_uds, elapsed_uds) = run_once(real);
        for (a, b) in p_sim.iter().flatten().zip(p_uds.iter().flatten()) {
            assert_eq!(a.data(), b.data(), "{mode}: sim vs uds diverged");
        }
        assert_eq!(bytes_sim, bytes_uds, "{mode}: byte accounting diverged");
        assert_eq!(elapsed_sim, 0.0);
        assert!(elapsed_uds > 0.0, "{mode}: no wall tx time measured");
    }
}
