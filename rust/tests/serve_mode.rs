//! Serving-mode integration suite: the open-loop admission layer is a
//! pure function of `(seed, knobs)`, the forward-only flow is
//! bit-identical between the `SimNet` reference and real sockets (the
//! CI serve-parity lane's contract in-process), the latency-objective
//! planner never loses to the makespan plan on its own metric, and the
//! paper's inference claim pins at the serving surface.

use mpcomp::cli::Args;
use mpcomp::compression::Spec;
use mpcomp::config::{RunSpec, Schedule, ServeKnobs, Surface, WireOpts};
use mpcomp::coordinator::serve::{self, ServeCompression, ServeOpts};
use mpcomp::coordinator::worker::{self, WorkerOpts};
use mpcomp::netsim::{arrivals, Backend, WireModel};
use mpcomp::planner::{search, search_latency, PlannerInputs};

fn serve_worker_opts(mode: &str) -> WorkerOpts {
    WorkerOpts {
        stages: 2,
        mb: 4, // unused by serve mode: admission decides the batch count
        link_elems: 300,
        schedule: Schedule::GPipe,
        spec: Spec::parse(mode).unwrap(),
        plan: None,
        seed: 7,
        wire: WireOpts {
            profile: "datacenter".into(),
            recv_timeout_s: 10.0,
            ..WireOpts::default()
        },
        steps: 1,
        dp: 1,
    }
}

fn knobs() -> ServeKnobs {
    ServeKnobs { rate_rps: 400.0, requests: 24, max_batch: 4, deadline_s: 0.01 }
}

// ---------------------------------------------------------------------------
// admission: deterministic, batch-bounded, deadline-bounded
// ---------------------------------------------------------------------------

#[test]
fn poisson_arrivals_and_admission_are_deterministic() {
    let a = arrivals::poisson(7, 500.0, 64);
    let b = arrivals::poisson(7, 500.0, 64);
    assert_eq!(a, b, "same seed and rate must replay the identical stream");
    assert_eq!(a.len(), 64);
    assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrivals are sorted");
    assert_ne!(a, arrivals::poisson(8, 500.0, 64), "a new seed draws a new stream");

    let (max_batch, deadline) = (4, 0.004);
    let batches = serve::admit(&a, max_batch, deadline);
    assert_eq!(batches, serve::admit(&a, max_batch, deadline));
    let covered: usize = batches.iter().map(|b| b.len).sum();
    assert_eq!(covered, a.len(), "admission covers every request exactly once");
    let mut next = 0;
    for b in &batches {
        assert_eq!(b.first, next, "admission is FIFO and contiguous");
        next = b.first + b.len;
        assert!(b.len >= 1 && b.len <= max_batch);
        // a full batch leaves with its last member; a deadline-cut
        // batch waits out the window opened by its oldest request
        if b.len == max_batch {
            assert_eq!(b.dispatch_s, a[b.first + b.len - 1]);
        } else {
            assert!((b.dispatch_s - (a[b.first] + deadline)).abs() < 1e-12);
        }
        assert!(b.dispatch_s - a[b.first] <= deadline + 1e-12, "nobody waits past the deadline");
    }
}

#[test]
fn serve_run_on_the_simulator_is_deterministic() {
    let opts = ServeOpts {
        stages: 4,
        schedule: Schedule::GPipe,
        link_elems: 1024,
        fwd_op_s: 0.002,
        seed: 11,
        knobs: knobs(),
        wire: WireOpts::default(),
        fault: Default::default(),
        plan: None,
        spec: Spec::parse("topk:10").unwrap(),
    };
    let (a, ma) = opts.run().unwrap();
    let (b, mb) = opts.run().unwrap();
    assert_eq!(a.requests, 24);
    assert_eq!((a.batches, a.bytes, a.raw_bytes), (b.batches, b.bytes, b.raw_bytes));
    assert_eq!((a.p50_s, a.p99_s, a.makespan_s), (b.p50_s, b.p99_s, b.makespan_s));
    assert_eq!(ma.serve_p99_s, mb.serve_p99_s);
    assert!(a.p50_s > 0.0 && a.p99_s >= a.p50_s);
    assert!(a.saturation_rps > 0.0 && a.throughput_rps > 0.0);
    assert!(a.wire_busy_frac > 0.0 && a.wire_busy_frac <= 1.0);
    assert!(a.bytes < a.raw_bytes, "top-10% must shrink the served wire");
}

// ---------------------------------------------------------------------------
// parity: serve-mode flow over real sockets matches the SimNet reference
// ---------------------------------------------------------------------------

#[test]
fn serve_parity_sim_vs_loopback_sockets() {
    for mode in ["topk:10", "ef21+topk:10"] {
        let opts = serve_worker_opts(mode);
        let k = knobs();
        let reference = worker::run_serve_reference(&opts, &k).unwrap();
        let again = worker::run_serve_reference(&opts, &k).unwrap();
        assert_eq!(reference.boxes, again.boxes, "{mode}: reference replay is deterministic");
        for backend in [Backend::Uds, Backend::Tcp] {
            let real = worker::run_serve_loopback(&opts, &k, backend).unwrap();
            worker::check(&reference, std::slice::from_ref(&real))
                .unwrap_or_else(|e| panic!("{mode} over {backend}: {e}"));
        }
    }
}

#[test]
fn serve_rendezvous_two_threads_uds_parity() {
    // The CI serve-parity lane's shape: two endpoint processes (threads
    // here) run the forward-only admission schedule across a real UDS
    // socket; each rank recomputes the identical batching locally and
    // the mailbox logs must match the reference bit for bit.
    let opts = serve_worker_opts("ef21+topk:10");
    let k = knobs();
    let dir = std::env::temp_dir().join(format!("mpcomp-serve-rv-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let addr = dir.to_str().unwrap().to_string();

    let (o0, k0, a0) = (opts.clone(), k.clone(), addr.clone());
    let h0 = std::thread::spawn(move || worker::run_serve_rank(&o0, &k0, 0, Backend::Uds, &a0));
    let (o1, k1) = (opts.clone(), k.clone());
    let h1 = std::thread::spawn(move || worker::run_serve_rank(&o1, &k1, 1, Backend::Uds, &addr));
    let s0 = h0.join().unwrap().unwrap();
    let s1 = h1.join().unwrap().unwrap();

    let reference = worker::run_serve_reference(&opts, &k).unwrap();
    worker::check(&reference, &[s0, s1]).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// the latency objective and the paper's serving claim
// ---------------------------------------------------------------------------

#[test]
fn latency_plan_never_loses_to_the_makespan_plan_on_p99() {
    let inputs = PlannerInputs {
        n_ranks: 2,
        schedule: Schedule::GPipe,
        n_mb: 4,
        fwd_op_s: 0.010,
        bwd_op_s: 0.020,
        recompute_s: 0.0,
        elems: vec![4096; 1],
        model: WireModel::wan(),
        capacity: 4,
        faults: None,
    };
    let k = knobs();
    let report = search_latency(&inputs, &k, 7).unwrap();
    assert!(
        report.p99_s <= report.makespan_plan_p99_s + 1e-9,
        "latency objective p99 {} !<= makespan plan p99 {}",
        report.p99_s,
        report.makespan_plan_p99_s
    );
    assert!(report.p50_s <= report.p99_s);
    report.plan.validate_for(2, 1, 4).unwrap();
    // both objectives search the same lattice; the makespan search must
    // still succeed on the identical inputs
    search(&inputs).unwrap();
}

#[test]
fn served_fidelity_pins_the_inference_claim() {
    let (elems, requests, seed) = (256, 16, 7);
    let fid = |mode: &str, wire| {
        serve::serve_fidelity(&Spec::parse(mode).unwrap(), wire, elems, requests, seed)
    };
    // a TopK-trained artifact served uncompressed is strictly worse
    // than served under its training-time specs...
    let topk_unc = fid("topk:10", ServeCompression::Uncompressed);
    let topk_ts = fid("topk:10", ServeCompression::TrainingSpecs);
    assert!(topk_unc + 0.05 < topk_ts, "topk uncompressed {topk_unc} !<< training {topk_ts}");
    assert!(topk_ts > 0.99);
    // ...while error-feedback artifacts serve uncompressed with
    // near-zero drop (the unbiased-on-average wire view)
    for mode in ["ef21+topk:10", "aqsgd+topk:10"] {
        let unc = fid(mode, ServeCompression::Uncompressed);
        let ts = fid(mode, ServeCompression::TrainingSpecs);
        assert!((unc - ts).abs() <= 0.1, "{mode}: |{unc} - {ts}| > 0.1");
        assert!(unc >= 0.9, "{mode}: uncompressed serving dropped to {unc}");
    }
}

// ---------------------------------------------------------------------------
// the typed config surface
// ---------------------------------------------------------------------------

fn parse_spec(cmdline: &str, surface: Surface) -> anyhow::Result<RunSpec> {
    let argv: Vec<String> = cmdline.split_whitespace().map(String::from).collect();
    let args = Args::parse(&argv, &[]).unwrap();
    RunSpec::from_args(&args, surface)
}

#[test]
fn typed_config_rejects_unknown_keys_with_the_catalog() {
    let err = parse_spec("serve --lnik-elems=4096", Surface::Serve).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("unknown config key 'lnik_elems'"), "{msg}");
    assert!(msg.contains("valid keys:"), "{msg}");
    assert!(msg.contains("link_elems"), "the catalog must name the right spelling: {msg}");
}

#[test]
fn legacy_spellings_shim_onto_the_typed_keys() {
    let rs = parse_spec(
        "worker --drop-p=0.05 --virtual-stages=2 --rate=100 --deadline-ms=5 --backend=udp",
        Surface::Worker,
    )
    .unwrap();
    assert_eq!(rs.fault_opts().drop_p, 0.05);
    assert_eq!(rs.train.schedule, Schedule::Interleaved { v: 2 });
    assert_eq!(rs.serve.rate_rps, 100.0);
    assert!((rs.serve.deadline_s - 0.005).abs() < 1e-12);
    assert_eq!(rs.wire_opts().unwrap().backend, Backend::Udp);
    // worker-surface defaults carry the legacy CLI defaults
    assert_eq!((rs.stages, rs.mb, rs.link_elems), (2, 4, 256));
    assert_eq!(rs.wire_opts().unwrap().recv_timeout_s, 20.0);
}
