//! Integration tests over the real AOT artifacts: kernel-vs-native
//! equivalence, end-to-end training behaviour, checkpointing, warm-start
//! accounting. Skipped (cleanly) if `make artifacts` has not run.

use mpcomp::compression::{ops, wire, Spec};
use mpcomp::config::{CompressImpl, TrainConfig};
use mpcomp::coordinator::Trainer;
use mpcomp::netsim::Transport as _;
use mpcomp::runtime::{lit_scalar, lit_vec, Runtime};
use mpcomp::util::rng::Rng;

fn artifacts() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        Some(Runtime::from_dir(dir).expect("loading artifacts"))
    } else {
        eprintln!("artifacts not built; skipping integration test");
        None
    }
}

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v, 0.0, 1.0);
    v
}

fn tiny_cfg(model: &str) -> TrainConfig {
    let mut cfg = TrainConfig::defaults(model);
    cfg.artifacts_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into();
    cfg.results_dir = std::env::temp_dir().join("mpcomp_itest").to_str().unwrap().into();
    if model == "cnn16" {
        cfg.train_size = 200;
        cfg.test_size = 100;
        cfg.epochs = 1;
        cfg.lr0 = 0.05;
    } else {
        cfg.train_size = 24;
        cfg.test_size = 8;
        cfg.batch_size = 8;
        cfg.epochs = 1;
        cfg.lr0 = 1e-3;
    }
    cfg
}

// ---------------------------------------------------------------------------
// L1 kernels (HLO artifacts) == native rust operators, bit-for-bit
// ---------------------------------------------------------------------------

#[test]
fn kernel_quantize_matches_native_all_bit_widths() {
    let Some(rt) = artifacts() else { return };
    let n = 16384; // smallest compiled link size
    let files = rt.manifest().compression_for(n).unwrap().clone();
    let x = randvec(n, 1);
    for bits in [2u8, 4, 6, 8] {
        let out = rt
            .call(&files.quant, &[lit_vec(&x), lit_scalar((1u32 << bits) as f32)])
            .unwrap();
        let got = out[0].to_vec::<f32>().unwrap();
        let want = ops::quantize(&x, bits);
        // XLA may fuse (x-lo)/rng*steps with FMA, so values exactly at a
        // rounding boundary can land one bucket away from the native
        // result (same tolerance rationale as python/tests). Everything
        // else must agree to float precision.
        let bucket = {
            let (mut lo, mut hi) = (f32::MAX, f32::MIN);
            for &v in &x {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            (hi - lo) / (((1u32 << bits) - 1) as f32)
        };
        let mut boundary = 0usize;
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            let d = (a - b).abs();
            if d > 1e-5 {
                assert!(d <= bucket + 1e-5, "bits={bits} i={i}: {a} vs {b}");
                boundary += 1;
            }
        }
        assert!(boundary < n / 100, "bits={bits}: {boundary} boundary mismatches");
        // the wire codec decodes to exactly the native values
        let decoded = wire::decode(&wire::encode_quant(&x, bits)).unwrap();
        assert_eq!(decoded, want, "wire bits={bits}");
    }
}

#[test]
fn kernel_topk_and_mask_match_native() {
    let Some(rt) = artifacts() else { return };
    let n = 16384;
    let files = rt.manifest().compression_for(n).unwrap().clone();
    let x = randvec(n, 2);
    let g = randvec(n, 3);
    for frac in [0.5f32, 0.1, 0.02] {
        let t = ops::threshold_for_frac(&x, frac);
        let out = rt.call(&files.topk, &[lit_vec(&x), lit_scalar(t)]).unwrap();
        let (want_x, want_m) = ops::apply_threshold(&x, t);
        assert_eq!(out[0].to_vec::<f32>().unwrap(), want_x);
        assert_eq!(out[1].to_vec::<f32>().unwrap(), want_m);
        // shared-index gradient masking
        let out2 = rt.call(&files.mask, &[lit_vec(&g), lit_vec(&want_m)]).unwrap();
        assert_eq!(out2[0].to_vec::<f32>().unwrap(), ops::mask_apply(&g, &want_m));
    }
}

#[test]
fn kernel_ef_steps_match_native() {
    let Some(rt) = artifacts() else { return };
    let n = 16384;
    let files = rt.manifest().compression_for(n).unwrap().clone();
    let x = randvec(n, 4);
    let buf = randvec(n, 5);
    // classic EF combine
    let s: Vec<f32> = x.iter().zip(&buf).map(|(a, b)| a + b).collect();
    let t = ops::threshold_for_frac(&s, 0.1);
    let out = rt
        .call(&files.ef_combine, &[lit_vec(&x), lit_vec(&buf), lit_scalar(t)])
        .unwrap();
    let (want_c, want_e) = ops::ef_combine(&x, &buf, 0.1);
    assert_eq!(out[0].to_vec::<f32>().unwrap(), want_c);
    assert_eq!(out[1].to_vec::<f32>().unwrap(), want_e);
    // EF21 / AQ-SGD delta step
    let delta: Vec<f32> = x.iter().zip(&buf).map(|(a, b)| a - b).collect();
    let t = ops::threshold_for_frac(&delta, 0.1);
    let out = rt
        .call(&files.delta_topk, &[lit_vec(&x), lit_vec(&buf), lit_scalar(t)])
        .unwrap();
    let (want, _) = ops::ef21_step(&x, &buf, 0.1);
    assert_eq!(out[0].to_vec::<f32>().unwrap(), want);
}

// ---------------------------------------------------------------------------
// end-to-end training behaviour
// ---------------------------------------------------------------------------

#[test]
fn baseline_training_reduces_loss() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = tiny_cfg("cnn16");
    cfg.epochs = 4;
    cfg.train_size = 400;
    let mut trainer = Trainer::new(rt, cfg).unwrap();
    let m = trainer.run().unwrap();
    let first = m.points.first().unwrap().train_loss;
    let last = m.points.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(last.is_finite() && m.points.last().unwrap().eval_off.is_finite());
}

#[test]
fn kernel_and_native_compression_train_identically() {
    // The two implementations must produce the same trajectory (they are
    // numerically identical operators); final params must match exactly.
    let Some(rt) = artifacts() else { return };
    let mut cfg = tiny_cfg("cnn16");
    cfg.spec = Spec::parse("topk:10").unwrap();
    cfg.compress_impl = CompressImpl::Kernel;
    let mut t1 = Trainer::new(rt, cfg.clone()).unwrap();
    t1.train_epoch(0).unwrap();
    let p1 = t1.stage_params();
    drop(t1);

    let rt = artifacts().unwrap();
    cfg.compress_impl = CompressImpl::Native;
    let mut t2 = Trainer::new(rt, cfg).unwrap();
    t2.train_epoch(0).unwrap();
    let p2 = t2.stage_params();

    for (s1, s2) in p1.iter().zip(&p2) {
        for (a, b) in s1.iter().zip(s2) {
            assert_eq!(a.data(), b.data(), "kernel vs native diverged");
        }
    }
}

#[test]
fn strong_compression_changes_uncompressed_inference() {
    // the paper's central observation: a model trained with strong TopK
    // behaves differently when compression is removed at inference
    let Some(rt) = artifacts() else { return };
    let mut cfg = tiny_cfg("cnn16");
    cfg.spec = Spec::parse("topk:5").unwrap();
    cfg.epochs = 2;
    let mut trainer = Trainer::new(rt, cfg).unwrap();
    trainer.run().unwrap();
    let on = trainer.evaluate(true).unwrap();
    let off = trainer.evaluate(false).unwrap();
    // they must at least differ measurably after compressed training
    assert!((on - off).abs() > 1e-6, "on={on} off={off}");
}

#[test]
fn warmup_epochs_send_uncompressed_bytes() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = tiny_cfg("cnn16");
    cfg.spec = Spec::parse("topk:10+warmup1").unwrap();
    cfg.epochs = 1; // only the warmup epoch runs
    let mut trainer = Trainer::new(rt, cfg).unwrap();
    trainer.run().unwrap();
    // all traffic was uncompressed during warmup
    let ledger = trainer.net.ledger();
    assert_eq!(ledger.total_bytes(), ledger.total_uncompressed_bytes());
}

#[test]
fn compression_reduces_wire_bytes() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = tiny_cfg("cnn16");
    cfg.spec = Spec::parse("quant:fw4-bw8").unwrap();
    let mut trainer = Trainer::new(rt, cfg).unwrap();
    let m = trainer.run().unwrap();
    let ratio = m.wire_raw_bytes as f64 / m.wire_bytes as f64;
    // fw 4-bit (8x) + bw 8-bit (4x) -> overall between 4x and 8x
    assert!(ratio > 4.0 && ratio < 8.5, "ratio {ratio}");
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(rt) = artifacts() else { return };
    let path = std::env::temp_dir().join(format!("mpcomp_itest_ckpt_{}", std::process::id()));
    let mut cfg = tiny_cfg("cnn16");
    cfg.save_checkpoint = Some(path.to_str().unwrap().into());
    let mut trainer = Trainer::new(rt, cfg.clone()).unwrap();
    trainer.run().unwrap();
    let trained = trainer.stage_params();
    drop(trainer);

    let rt = artifacts().unwrap();
    let mut cfg2 = tiny_cfg("cnn16");
    cfg2.init_checkpoint = Some(path.to_str().unwrap().into());
    let trainer2 = Trainer::new(rt, cfg2).unwrap();
    let loaded = trainer2.stage_params();
    for (s1, s2) in trained.iter().zip(&loaded) {
        for (a, b) in s1.iter().zip(s2) {
            assert_eq!(a.data(), b.data());
        }
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn lm_task_trains_and_evaluates() {
    let Some(rt) = artifacts() else { return };
    let mut cfg = tiny_cfg("lm128");
    cfg.spec = Spec::parse("topk:30:shared").unwrap();
    let mut trainer = Trainer::new(rt, cfg).unwrap();
    let m = trainer.run().unwrap();
    let loss = m.points.last().unwrap().eval_off;
    // must be finite and below uniform (ln 128 = 4.85) after an epoch…
    // barely — allow a loose bound since this is one tiny epoch
    assert!(loss.is_finite() && loss < 5.5, "lm eval loss {loss}");
}

#[test]
fn schedules_agree_on_result() {
    // GPipe and 1F1B must compute the same gradients (order differs only
    // across microbatches within a batch, and accumulation commutes up
    // to f32 rounding; with feedback disabled results are identical
    // because each microbatch's path is independent).
    let Some(rt) = artifacts() else { return };
    let mut cfg = tiny_cfg("cnn16");
    cfg.spec = Spec::parse("topk:10").unwrap();
    let mut t1 = Trainer::new(rt, cfg.clone()).unwrap();
    t1.train_epoch(0).unwrap();
    let p1 = t1.stage_params();
    drop(t1);

    let rt = artifacts().unwrap();
    cfg.schedule = mpcomp::config::Schedule::OneFOneB;
    let mut t2 = Trainer::new(rt, cfg).unwrap();
    t2.train_epoch(0).unwrap();
    let p2 = t2.stage_params();
    for (s1, s2) in p1.iter().zip(&p2) {
        for (a, b) in s1.iter().zip(s2) {
            let max_diff = a
                .data()
                .iter()
                .zip(b.data())
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-5, "schedules diverged: {max_diff}");
        }
    }
}
