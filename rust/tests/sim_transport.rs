//! Simulated-transport integration tests.
//!
//! The first half runs with no AOT artifacts: it drives `CompressedLink`
//! end to end over `SimNet` with the native operators and cross-checks
//! the bytes the transport charges against the wire codecs' actual
//! encodings. The second half (artifacts-gated, like
//! `tests/integration.rs`) asserts the core refactor guarantee: routing
//! training through the event-driven transport changes *timing only* —
//! trained parameters are bit-identical across wire models and queue
//! capacities, exactly as the pre-simulator single-threaded replay
//! produced them.

use mpcomp::compression::{ops, wire, Feedback, Method, Spec};
use mpcomp::config::{CompressImpl, Schedule, TrainConfig};
use mpcomp::coordinator::feedback::FeedbackState;
use mpcomp::coordinator::{CompressedLink, Trainer};
use mpcomp::netsim::{SimNet, WireModel};
use mpcomp::runtime::{artifacts::CompressionFiles, Manifest, Runtime};
use mpcomp::tensor::Tensor;
use mpcomp::util::rng::Rng;

/// Enough manifest for a `Runtime` handle; no executables are touched
/// on the `CompressImpl::Native` path.
const EMPTY_MANIFEST: &str = r#"{"block": 4, "models": {}, "compression": {}}"#;

fn native_runtime() -> Runtime {
    let m = Manifest::parse(EMPTY_MANIFEST, std::path::PathBuf::from("/tmp")).unwrap();
    Runtime::new(m).unwrap()
}

fn dummy_files() -> CompressionFiles {
    CompressionFiles {
        quant: "q".into(),
        topk: "t".into(),
        mask: "m".into(),
        delta_topk: "d".into(),
        ef_combine: "e".into(),
    }
}

fn randt(n: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v, 0.0, 1.0);
    Tensor::from_vec(v)
}

// ---------------------------------------------------------------------------
// link-level: charged bytes == the codec's real encoding
// ---------------------------------------------------------------------------

#[test]
fn link_charges_exactly_what_the_codecs_encode() {
    let rt = native_runtime();
    let n = 4096;
    let x = randt(n, 1);
    for mode in ["none", "quant:fw4-bw6", "topk:10", "topk:30"] {
        let spec = Spec::parse(mode).unwrap();
        let mut link = CompressedLink::new(0, n, n, dummy_files());
        let mut net = SimNet::new(1, WireModel::default());
        let (out, arrival) = link
            .forward(&rt, &spec, CompressImpl::Native, &x, 0, true, &mut net, 0.0)
            .unwrap();
        let charged = net.total_bytes() as usize;
        let encoded = match spec.method {
            Method::None => wire::encode_raw(x.data()),
            Method::Quant { fw_bits, .. } => wire::encode_quant(x.data(), fw_bits),
            Method::TopK { .. } => wire::encode_sparse(out.data(), out.count_nonzero()),
        };
        assert_eq!(charged, encoded.len(), "{mode}: charged != encoded");
        assert!(arrival > 0.0, "{mode}: arrival {arrival}");
        // encode -> decode identity: what a receiver would reconstruct
        // is exactly the tensor the link handed downstream (raw decodes
        // to x itself, quant to ops::quantize(x), sparse to the mask)
        let decoded = wire::decode(&encoded).unwrap();
        assert_eq!(decoded, out.data(), "{mode}: wire roundtrip != link output");
        assert_eq!(net.total_uncompressed_bytes() as usize, wire::raw_wire_bytes(n));
    }
}

#[test]
fn shared_index_gradient_charges_masked_support() {
    let rt = native_runtime();
    let n = 2048;
    let x = randt(n, 2);
    let g = randt(n, 3);
    let spec = Spec::parse("topk:10:shared").unwrap();
    let mut link = CompressedLink::new(0, n, n, dummy_files());
    let mut net = SimNet::new(1, WireModel::default());
    link.forward(&rt, &spec, CompressImpl::Native, &x, 7, true, &mut net, 0.0).unwrap();
    let fwd_bytes = net.total_bytes() as usize;
    let (gout, _) =
        link.backward(&rt, &spec, CompressImpl::Native, &g, 7, true, &mut net, 0.0).unwrap();
    let bwd_bytes = net.total_bytes() as usize - fwd_bytes;
    let k = gout.count_nonzero();
    assert_eq!(bwd_bytes, wire::sparse_wire_bytes(n, k));
    assert_eq!(bwd_bytes, wire::encode_sparse(gout.data(), k).len());
    // the gradient support is a subset of the activation mask's budget
    assert!(k <= mpcomp::compression::ops::budget(n, 0.1));
}

#[test]
fn link_ef21_ships_delta_frames_reconstructed_by_the_mirror() {
    // the link's encode path has no local-reconstruction shortcut left:
    // it charges exactly the delta frame the shared state machine
    // produces, and hands downstream what its receiver mirror decodes
    let rt = native_runtime();
    let n = 4096;
    let spec = Spec::parse("ef21+topk:10").unwrap();
    let mut link = CompressedLink::new(0, n, n, dummy_files());
    let mut net = SimNet::new(1, WireModel::default());
    let mut shadow = FeedbackState::new();
    let plain = wire::sparse_wire_bytes(n, ops::budget(n, 0.1));
    for key in 0..3u64 {
        let x = randt(n, 20 + key);
        let before = net.total_bytes() as usize;
        let (out, _) = link
            .forward(&rt, &spec, CompressImpl::Native, &x, key, true, &mut net, 0.0)
            .unwrap();
        let charged = net.total_bytes() as usize - before;
        let (frame, recon) = shadow.sender_encode(Feedback::Ef21, key, x.data(), 0.1).unwrap();
        assert_eq!(charged, frame.len(), "key {key}: charged != delta frame");
        assert!(charged < plain, "key {key}: delta {charged} !< plain sparse {plain}");
        assert_eq!(out.data(), &recon[..], "key {key}: mirror output != sender view");
    }
    // the footprint metric counts both protocol halves (fwd only here)
    assert_eq!(link.feedback_memory_bytes(), 2 * 4 * n);
    link.reset();
    assert_eq!(link.feedback_memory_bytes(), 0);
}

#[test]
fn link_aqsgd_bootstraps_then_ships_near_empty_deltas() {
    let rt = native_runtime();
    let n = 2048;
    let spec = Spec::parse("aqsgd+topk:30").unwrap();
    let mut link = CompressedLink::new(0, n, n, dummy_files());
    let mut net = SimNet::new(1, WireModel::default());
    let x = randt(n, 9);
    // first visit of sample 7: uncompressed bootstrap frame
    let (out, _) = link
        .forward(&rt, &spec, CompressImpl::Native, &x, 7, true, &mut net, 0.0)
        .unwrap();
    assert_eq!(net.total_bytes() as usize, wire::delta_bootstrap_bytes(n));
    assert_eq!(out.data(), x.data());
    // revisit with identical activations: the delta is exactly zero
    let before = net.total_bytes() as usize;
    let (out, _) = link
        .forward(&rt, &spec, CompressImpl::Native, &x, 7, true, &mut net, 0.0)
        .unwrap();
    let update = net.total_bytes() as usize - before;
    assert!(update < 64, "zero-delta update frame is near-empty, got {update} B");
    assert_eq!(out.data(), x.data(), "reconstruction tracks the buffer");
    // gradients under AQ-SGD are plain TopK (activations-only feedback)
    let g = randt(n, 10);
    let before = net.total_bytes() as usize;
    let (gout, _) = link
        .backward(&rt, &spec, CompressImpl::Native, &g, 7, true, &mut net, 0.0)
        .unwrap();
    let bwd = net.total_bytes() as usize - before;
    assert_eq!(bwd, wire::sparse_wire_bytes(n, gout.count_nonzero()));
}

#[test]
fn link_messages_contend_for_bandwidth() {
    // three uncompressed messages handed to the link at the same virtual
    // time serialize: arrivals are spaced by at least the tx time
    let rt = native_runtime();
    let n = 8192;
    let spec = Spec::none();
    let mut link = CompressedLink::new(0, n, n, dummy_files());
    let model = WireModel::default();
    let mut net = SimNet::new(1, model);
    let tx = model.tx_time(wire::raw_wire_bytes(n));
    let mut last = 0.0;
    for key in 0..3u64 {
        let x = randt(n, 10 + key);
        let (_, arrival) = link
            .forward(&rt, &spec, CompressImpl::Native, &x, key, true, &mut net, 0.0)
            .unwrap();
        if key > 0 {
            assert!(
                arrival - last >= tx - 1e-12,
                "messages overlapped: {last} -> {arrival} (tx {tx})"
            );
        }
        last = arrival;
    }
    assert!((net.busy_time() - 3.0 * tx).abs() < 1e-9);
}

// ---------------------------------------------------------------------------
// trainer-level (artifacts-gated): timing changes, math does not
// ---------------------------------------------------------------------------

fn artifacts() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.json").exists() {
        Some(Runtime::from_dir(dir).expect("loading artifacts"))
    } else {
        eprintln!("artifacts not built; skipping integration test");
        None
    }
}

fn tiny_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::defaults("cnn16");
    cfg.artifacts_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into();
    cfg.results_dir = std::env::temp_dir().join("mpcomp_simtest").to_str().unwrap().into();
    cfg.train_size = 200;
    cfg.test_size = 100;
    cfg.epochs = 1;
    cfg.lr0 = 0.05;
    cfg.sim_op_time = Some(0.020); // deterministic virtual op cost
    cfg
}

/// One trained run; returns (params, simulated makespan).
fn run_once(cfg: TrainConfig) -> (Vec<Vec<Tensor>>, f64) {
    let rt = artifacts().unwrap();
    let mut trainer = Trainer::new(rt, cfg).unwrap();
    let m = trainer.run().unwrap();
    (trainer.stage_params(), m.sim_makespan_s)
}

#[test]
fn training_is_bit_identical_across_wire_models() {
    // The event-driven transport must be timing-only: the same seed
    // trained over a WAN, a datacenter link, or a capacity-1 queue
    // yields bit-identical parameters (the single-threaded replay
    // result), while the measured makespans differ.
    if artifacts().is_none() {
        return;
    }
    for mode in ["none", "topk:10"] {
        let mut base = tiny_cfg();
        base.spec = Spec::parse(mode).unwrap();
        base.compress_impl = CompressImpl::Native;

        let (p_wan, mk_wan) = run_once(base.clone());
        let mut dc = base.clone();
        dc.wire = "datacenter".into();
        let (p_dc, mk_dc) = run_once(dc);
        let mut tight = base.clone();
        tight.sim_queue_cap = 1;
        let (p_tight, _) = run_once(tight);

        for (a, b) in p_wan.iter().flatten().zip(p_dc.iter().flatten()) {
            assert_eq!(a.data(), b.data(), "{mode}: wan vs datacenter diverged");
        }
        for (a, b) in p_wan.iter().flatten().zip(p_tight.iter().flatten()) {
            assert_eq!(a.data(), b.data(), "{mode}: queue capacity changed math");
        }
        assert!(mk_wan > 0.0 && mk_dc > 0.0, "{mode}: makespan not measured");
        assert!(
            mk_wan >= mk_dc,
            "{mode}: WAN makespan {mk_wan} < datacenter {mk_dc}"
        );
    }
}

#[test]
fn schedules_still_agree_through_the_transport() {
    // GPipe vs 1F1B through SimNet: same gradients (up to accumulation
    // rounding), different virtual timing.
    if artifacts().is_none() {
        return;
    }
    let mut cfg = tiny_cfg();
    cfg.spec = Spec::parse("topk:10").unwrap();
    cfg.compress_impl = CompressImpl::Native;
    let (p1, _) = run_once(cfg.clone());
    cfg.schedule = Schedule::OneFOneB;
    let (p2, _) = run_once(cfg.clone());
    // interleaved:2 folds cnn16's 4 stages onto 2 ranks (ring wire,
    // per-boundary channels) — the math must not notice
    cfg.schedule = Schedule::Interleaved { v: 2 };
    let (p3, _) = run_once(cfg);
    for (p_other, label) in [(&p2, "1f1b"), (&p3, "interleaved:2")] {
        for (a, b) in p1.iter().flatten().zip(p_other.iter().flatten()) {
            let max_diff = a
                .data()
                .iter()
                .zip(b.data())
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-5, "{label} diverged through transport: {max_diff}");
        }
    }
}
