//! Telemetry-layer integration tests: these own the crate's global
//! telemetry store (gate, per-thread buffers, global drain target), so
//! they live in their own test binary — every test serializes on
//! `telemetry::test_guard()` and leaves the layer disabled and reset.
//!
//! Covered here: the disabled-mode cost contract (zero allocations,
//! zero clock reads on the record path), SimNet snapshot determinism
//! (same seed ⇒ bit-identical JSON), the measured-regime roll-up, and
//! the full observe → export → replan loop through a Chrome trace file.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use mpcomp::compression::Spec;
use mpcomp::config::{Schedule, WireOpts};
use mpcomp::coordinator::{worker, WorkerOpts};
use mpcomp::netsim::Dir;
use mpcomp::planner;
use mpcomp::telemetry;

// ---------------------------------------------------------------------------
// counting allocator: per-thread allocation counter over the system
// allocator, so the zero-allocation assertion ignores other threads
// ---------------------------------------------------------------------------

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

fn bump() {
    // try_with: TLS may be unavailable during thread teardown, and the
    // allocator must never panic (or allocate) on its own account
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        bump();
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        bump();
        System.realloc(p, l, n)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn worker_opts(seed: u64) -> WorkerOpts {
    WorkerOpts {
        stages: 2,
        mb: 4,
        link_elems: 256,
        schedule: Schedule::GPipe,
        spec: Spec::parse("topk:10").unwrap(),
        plan: None,
        seed,
        wire: WireOpts::default(),
        steps: 2,
        dp: 1,
    }
}

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("mpcomp-telemetry-{}-{name}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

// ---------------------------------------------------------------------------
// disabled-mode cost contract
// ---------------------------------------------------------------------------

#[test]
fn disabled_mode_allocates_nothing_and_reads_no_clock() {
    let _g = telemetry::test_guard();
    telemetry::reset();
    telemetry::set_enabled(false);

    // warm the hooks once so lazy statics can't be charged to the loop
    telemetry::set_channel_hint(1);
    telemetry::on_send(0, Dir::Fwd, 8, 8, 0.0, 0.0, 0.0);
    telemetry::timer().stop(0, "warm", "codec", 0);

    let clocks_before = telemetry::clock_reads();
    let allocs_before = thread_allocs();
    for i in 0..10_000u64 {
        telemetry::set_channel_hint(i as u32);
        telemetry::on_send(0, Dir::Fwd, 100, 400, 0.001, 0.01, 0.0);
        telemetry::on_recv_wait(0, Dir::Bwd, 0.002);
        telemetry::on_retransmit(0, Dir::Fwd);
        telemetry::span_at(0, "fwd", "op", 0.0, 1.0, i);
        telemetry::timer().stop(0, "encode", "codec", i);
    }
    assert_eq!(thread_allocs() - allocs_before, 0, "disabled record path allocated");
    assert_eq!(
        telemetry::clock_reads(),
        clocks_before,
        "disabled record path read the clock"
    );

    // and nothing was recorded
    let snap = telemetry::snapshot();
    assert!(snap.links.is_empty());
    assert!(telemetry::take_spans().is_empty());
}

// ---------------------------------------------------------------------------
// SimNet snapshot determinism
// ---------------------------------------------------------------------------

/// One traced SimNet reference run; returns the snapshot JSON string.
fn traced_reference_snapshot(seed: u64) -> String {
    telemetry::reset();
    telemetry::set_enabled(true);
    telemetry::set_spans(true);
    telemetry::set_virtual_clock(true);
    worker::run_reference(&worker_opts(seed)).unwrap();
    let json = telemetry::snapshot().to_json().to_string();
    telemetry::set_enabled(false);
    telemetry::reset();
    json
}

#[test]
fn simnet_snapshot_is_bit_deterministic_per_seed() {
    let _g = telemetry::test_guard();
    let a = traced_reference_snapshot(3);
    let b = traced_reference_snapshot(3);
    assert_eq!(a, b, "same seed must produce a bit-identical snapshot");
    // (a different seed changes payload *values*, not frame sizes, so
    // it is NOT asserted to differ — the snapshot only sees bytes/time)

    // sanity on what the deterministic snapshot contains
    let j = mpcomp::util::json::Json::parse(&a).unwrap();
    assert_eq!(j.get("version").unwrap().num().unwrap(), 1.0);
    assert_eq!(j.get("clock").unwrap().str().unwrap(), "virtual");
    assert!(!j.get("links").unwrap().arr().unwrap().is_empty());
    let m = j.get("measured").unwrap();
    assert!(m.get("bandwidth_bytes_per_s").unwrap().num().unwrap() > 0.0);
}

// ---------------------------------------------------------------------------
// measured-regime roll-up (drives the public hooks end to end)
// ---------------------------------------------------------------------------

#[test]
fn snapshot_derives_the_measured_regime_from_hooks() {
    let _g = telemetry::test_guard();
    telemetry::reset();
    telemetry::set_enabled(true);
    telemetry::set_spans(true);
    telemetry::set_virtual_clock(true);
    // two sends at 1 MB/s with 10 ms latency, one 0.02 s fwd op span
    telemetry::set_channel_hint(3);
    telemetry::on_send(0, Dir::Fwd, 1000, 4000, 0.001, 0.010, 0.0);
    telemetry::on_send(0, Dir::Fwd, 3000, 4000, 0.003, 0.010, 0.5);
    telemetry::span_at(0, "fwd", "op", 1.0, 1.02, 7);
    let snap = telemetry::snapshot();
    telemetry::set_enabled(false);
    telemetry::reset();

    assert_eq!(snap.clock, "virtual");
    assert_eq!(snap.links.len(), 1);
    let r = &snap.links[0];
    assert_eq!((r.link, r.dir.as_str(), r.channel), (0, "fwd", 3));
    assert_eq!(r.frames, 2);
    assert_eq!(r.wire_bytes, 4000);
    assert_eq!(r.raw_bytes, 8000);
    assert!((r.queue_wait_s - 0.5).abs() < 1e-12);
    assert_eq!(r.lat_min_s, Some(0.010));
    let m = snap.measured;
    assert!((m.bandwidth_bytes_per_s.unwrap() - 1e6).abs() < 1e-6);
    assert_eq!(m.latency_s, Some(0.010));
    assert!((m.fwd_op_s.unwrap() - 0.02).abs() < 1e-12);
    assert_eq!(m.bwd_op_s, None);
}

#[test]
fn spans_off_keeps_counters() {
    let _g = telemetry::test_guard();
    telemetry::reset();
    telemetry::set_enabled(true);
    telemetry::set_spans(false);
    telemetry::on_send(1, Dir::Bwd, 64, 256, 0.001, 0.0, 0.0);
    telemetry::span_at(0, "fwd", "op", 0.0, 1.0, 0);
    telemetry::timer().stop(0, "encode", "codec", 0);
    let snap = telemetry::snapshot();
    let spans = telemetry::take_spans();
    telemetry::set_enabled(false);
    telemetry::set_spans(true);
    telemetry::reset();
    assert_eq!(snap.links.len(), 1, "telemetry.spans=false must not drop counters");
    assert!(spans.is_empty(), "spans recorded while telemetry.spans=false");
}

// ---------------------------------------------------------------------------
// the full loop: trace a run, export Chrome JSON, replan from the file
// ---------------------------------------------------------------------------

#[test]
fn chrome_trace_round_trips_into_replanning() {
    let _g = telemetry::test_guard();
    telemetry::reset();
    telemetry::set_enabled(true);
    telemetry::set_spans(true);
    telemetry::set_virtual_clock(true);
    worker::run_reference(&worker_opts(5)).unwrap();
    let snap = telemetry::snapshot();
    let spans = telemetry::take_spans();
    telemetry::set_enabled(false);
    telemetry::reset();

    let path = tmp("trace.json");
    telemetry::chrome::export(&path, &snap, &spans).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let j = mpcomp::util::json::Json::parse(&text).unwrap();
    assert_eq!(j.get("displayTimeUnit").unwrap().str().unwrap(), "ms");
    let events = j.get("traceEvents").unwrap().arr().unwrap();
    assert!(!events.is_empty(), "trace has no events");
    // thread-name metadata + complete events, Chrome's minimum shape
    assert!(events.iter().any(|e| e.get("ph").unwrap().str().unwrap() == "M"));
    assert!(events.iter().any(|e| e.get("ph").unwrap().str().unwrap() == "X"));

    // the embedded snapshot is a valid replanning input
    let measured = telemetry::snapshot::Measured::load(&path).unwrap();
    assert!(measured.bandwidth_bytes_per_s.unwrap() > 0.0);
    let mut inputs = planner::PlannerInputs {
        n_ranks: 2,
        schedule: Schedule::OneFOneB,
        n_mb: 4,
        fwd_op_s: 0.020,
        bwd_op_s: 0.040,
        recompute_s: 0.0,
        elems: vec![256; 1],
        model: mpcomp::netsim::WireModel::datacenter(),
        capacity: mpcomp::netsim::DEFAULT_QUEUE_CAPACITY,
        faults: None,
    };
    let applied = planner::apply_measured(&mut inputs, &measured).unwrap();
    assert!(applied.contains(&"bandwidth_bytes_per_s"));
    planner::search(&inputs).unwrap();
    let _ = std::fs::remove_file(&path);
}
