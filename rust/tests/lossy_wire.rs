//! Lossy-wire fault-injection suite: the UDP reliability layer and the
//! `SimNet` fault models must both deliver the *exact* bytes the
//! protocol sent — loss, duplication, and reordering may cost time and
//! retransmits, never content. The acceptance contract is `worker
//! --check`-style parity against the clean `SimNet` reference while
//! ~5% of data datagrams are dropped on the floor.

use std::sync::Mutex;
use std::time::Duration;

use mpcomp::compression::{wire, Feedback, Spec};
use mpcomp::config::{Schedule, WireOpts};
use mpcomp::coordinator::feedback::FeedbackState;
use mpcomp::coordinator::worker::{self, WorkerOpts};
use mpcomp::netsim::{
    Backend, Dir, FaultModel, Payload, SimNet, Transport, UdpFaults, UdpTransport, WireModel,
};
use mpcomp::util::rng::Rng;

/// `UdpFaults::from_env` knobs are process-global; tests that set them
/// serialize here so a parallel test never reads a half-configured
/// environment.
static ENV_LOCK: Mutex<()> = Mutex::new(());

struct EnvFaults;

impl EnvFaults {
    fn set(drop_p: f64, seed: u64) -> EnvFaults {
        std::env::set_var("MPCOMP_UDP_DROP_P", drop_p.to_string());
        std::env::set_var("MPCOMP_UDP_FAULT_SEED", seed.to_string());
        EnvFaults
    }
}

impl Drop for EnvFaults {
    fn drop(&mut self) {
        std::env::remove_var("MPCOMP_UDP_DROP_P");
        std::env::remove_var("MPCOMP_UDP_FAULT_SEED");
    }
}

fn worker_opts(mode: &str, link_elems: usize, steps: usize) -> WorkerOpts {
    WorkerOpts {
        stages: 2,
        mb: 4,
        link_elems,
        schedule: Schedule::GPipe,
        spec: Spec::parse(mode).unwrap(),
        plan: None,
        seed: 5,
        wire: WireOpts {
            profile: "datacenter".into(),
            recv_timeout_s: 10.0,
            ..WireOpts::default()
        },
        steps,
        dp: 1,
    }
}

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 0.0, 1.0);
    v
}

// ---------------------------------------------------------------------------
// delta frames across the lossy reliability layer
// ---------------------------------------------------------------------------

#[test]
fn delta_frames_survive_drop_dup_and_reorder_on_udp() {
    // EF21 tag-4 frames carry a generation counter and a payload
    // digest, so any reliability bug — a lost fragment, a double
    // apply, an out-of-order delivery — turns into a typed
    // `GenerationSkew`/`DigestMismatch` here. A run over an aggressive
    // fault cocktail must replay cleanly.
    let n = 3000; // multi-fragment frames: each crosses several MTUs
    let gens = 8u64;
    let faults = UdpFaults { drop_p: 0.2, dup_p: 0.15, reorder_p: 0.2, seed: 11 };
    let mut net =
        UdpTransport::loopback(1, WireModel::datacenter(), Duration::from_secs(10), &faults)
            .unwrap();

    let mut sender = FeedbackState::new();
    let mut frames = Vec::new();
    for g in 0..gens {
        let (frame, _) = sender.sender_encode(Feedback::Ef21, g, &randvec(n, 100 + g), 0.1).unwrap();
        net.send(0, Dir::Fwd, g, Payload::Bytes(&frame), wire::raw_wire_bytes(n), 0.0).unwrap();
        frames.push(frame);
    }

    let mut mirror = FeedbackState::new();
    for (g, sent) in frames.iter().enumerate() {
        let f = net.recv(0, Dir::Fwd, g as u64).unwrap();
        let payload = f.payload.as_deref().unwrap();
        assert_eq!(payload, &sent[..], "gen {g}: bytes must survive the lossy wire");
        let df = wire::decode_delta(payload).unwrap();
        mirror
            .apply_frame(Feedback::Ef21, &df, n)
            .unwrap_or_else(|e| panic!("gen {g}: mirror replay failed: {e:?}"));
    }
    assert_eq!(mirror.gen(), gens, "every generation applied exactly once");

    net.shutdown().unwrap();
    let (fresh, retransmits) = net.datagram_stats();
    assert!(fresh > gens, "multi-fragment frames must cost more datagrams than frames");
    assert!(retransmits > 0, "20% drop must exercise the retransmit path");
}

// ---------------------------------------------------------------------------
// SimNet fault models: timing-only, content-identical
// ---------------------------------------------------------------------------

#[test]
fn simnet_faults_delay_but_never_corrupt_deliveries() {
    // The simulator prices loss as retransmit rounds — it must never
    // alter payload bytes, so a faulted run's delivery log stays
    // bit-identical to the clean run while its arrivals only slip
    // later.
    let n = 1200;
    let mut sender = FeedbackState::new();
    let mut frames = Vec::new();
    for g in 0..6u64 {
        let (frame, _) = sender.sender_encode(Feedback::Ef21, g, &randvec(n, 40 + g), 0.1).unwrap();
        frames.push(frame);
    }

    let mut clean = SimNet::new(1, WireModel::wan());
    let mut lossy = SimNet::new(1, WireModel::wan()).with_faults(FaultModel {
        drop_p: 0.3,
        dup_p: 0.1,
        reorder_window: 2,
        jitter_s: 0.002,
        seed: 23,
        ..FaultModel::default()
    });
    for (g, frame) in frames.iter().enumerate() {
        let key = g as u64;
        clean.send(0, Dir::Fwd, key, Payload::Bytes(frame), frame.len(), 0.0).unwrap();
        lossy.send(0, Dir::Fwd, key, Payload::Bytes(frame), frame.len(), 0.0).unwrap();
    }
    // the simulator keeps tensors in-process (payload is None); the
    // frames the protocol would replay are the sender-side copies, so
    // fault models can shift *when* a frame lands but never *what*
    let mut mirror = FeedbackState::new();
    let mut slipped = 0;
    for (g, sent) in frames.iter().enumerate() {
        let key = g as u64;
        let c = clean.recv(0, Dir::Fwd, key).unwrap();
        let l = lossy.recv(0, Dir::Fwd, key).unwrap();
        assert_eq!((c.key, c.bytes), (l.key, l.bytes), "gen {g}: same delivery log entry");
        assert!(l.payload.is_none(), "sim keeps tensors in-process even under faults");
        assert!(l.arrival >= c.arrival, "gen {g}: faults can only delay arrivals");
        if l.arrival > c.arrival {
            slipped += 1;
        }
        let df = wire::decode_delta(sent).unwrap();
        mirror.apply_frame(Feedback::Ef21, &df, n).unwrap();
    }
    assert!(slipped > 0, "30% drop + jitter must delay at least one arrival");
    assert_eq!(mirror.gen(), frames.len() as u64);
}

// ---------------------------------------------------------------------------
// worker --check parity under ~5% injected loss
// ---------------------------------------------------------------------------

#[test]
fn udp_loopback_parity_under_five_percent_loss() {
    // The CI lossy lane's contract in-process: a full EF21 pipeline
    // schedule over lossy UDP loopback is bit-identical to the clean
    // `SimNet` reference.
    let _g = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _env = EnvFaults::set(0.05, 0x1dcb);
    let opts = worker_opts("ef21+topk:10", 300, 3);
    let reference = worker::run_reference(&opts).unwrap();
    let real = worker::run_loopback(&opts, Backend::Udp).unwrap();
    worker::check(&reference, std::slice::from_ref(&real)).unwrap();
}

#[test]
fn endpoint_rendezvous_two_threads_udp_under_loss() {
    // Two endpoint processes (threads here) rendezvous over real UDP
    // sockets with 5% of data datagrams dropped; each rank's mailbox
    // log must still match the reference bit for bit.
    let _g = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _env = EnvFaults::set(0.05, 0x2d5f);
    let opts = worker_opts("ef21+topk:10", 2048, 3);
    let addr = "127.0.0.1:39410".to_string();

    let o0 = opts.clone();
    let a0 = addr.clone();
    let h0 = std::thread::spawn(move || worker::run_rank(&o0, 0, Backend::Udp, &a0));
    let o1 = opts.clone();
    let h1 = std::thread::spawn(move || worker::run_rank(&o1, 1, Backend::Udp, &addr));
    let s0 = h0.join().unwrap().unwrap();
    let s1 = h1.join().unwrap().unwrap();

    let reference = worker::run_reference(&opts).unwrap();
    worker::check(&reference, &[s0, s1]).unwrap();
}
