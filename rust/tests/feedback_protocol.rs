//! Receiver-side error-feedback protocol tests: EF21/AQ-SGD parity
//! between the `SimNet` reference and real sockets, and fault injection
//! — truncated/corrupt/reordered delta frames and a mid-stream
//! disconnect must surface as typed `TransportError`/decode/
//! `FeedbackError`s with **no panic and no silent state skew**, on both
//! transports. None of this needs AOT artifacts.

use std::time::Duration;

use mpcomp::compression::{wire, Feedback, Spec};
use mpcomp::config::{Schedule, WireOpts};
use mpcomp::coordinator::feedback::{FeedbackError, FeedbackState};
use mpcomp::coordinator::worker::{self, WorkerOpts};
use mpcomp::netsim::{
    Backend, Dir, Payload, RealTransport, SimNet, Transport, TransportError, WireModel,
};
use mpcomp::util::rng::Rng;

fn worker_opts(mode: &str, link_elems: usize, steps: usize) -> WorkerOpts {
    WorkerOpts {
        stages: 2,
        mb: 4,
        link_elems,
        schedule: Schedule::GPipe,
        spec: Spec::parse(mode).unwrap(),
        plan: None,
        seed: 5,
        wire: WireOpts {
            profile: "datacenter".into(),
            recv_timeout_s: 10.0,
            ..WireOpts::default()
        },
        steps,
        dp: 1,
    }
}

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 0.0, 1.0);
    v
}

// ---------------------------------------------------------------------------
// parity: the acceptance contract over real sockets
// ---------------------------------------------------------------------------

#[test]
fn ef_parity_over_real_sockets() {
    // `worker --check`-style parity with feedback=ef21|aqsgd: the real
    // transports deliver byte-identical delta frames in the same order
    // as the SimNet reference, and every receiver mirror replays them
    // without a generation or digest error.
    for mode in ["ef21+topk:10", "aqsgd+topk:10"] {
        let opts = worker_opts(mode, 300, 3);
        let reference = worker::run_reference(&opts).unwrap();
        for backend in [Backend::Uds, Backend::Tcp] {
            let real = worker::run_loopback(&opts, backend).unwrap();
            worker::check(&reference, std::slice::from_ref(&real))
                .unwrap_or_else(|e| panic!("{mode} over {backend}: {e}"));
        }
    }
}

#[test]
fn endpoint_rendezvous_two_threads_ef21_uds() {
    // The CI loopback job's shape: two endpoint processes (threads
    // here) run the EF21 delta protocol across a real UDS socket; each
    // rank's mailbox log must be bit-identical to the reference, and
    // the measured EF traffic must undercut the feedback=none baseline.
    let opts = worker_opts("ef21+topk:10", 4096, 3);
    let dir = std::env::temp_dir().join(format!("mpcomp-ef-rv-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let addr = dir.to_str().unwrap().to_string();

    let o0 = opts.clone();
    let a0 = addr.clone();
    let h0 = std::thread::spawn(move || worker::run_rank(&o0, 0, Backend::Uds, &a0));
    let o1 = opts.clone();
    let h1 = std::thread::spawn(move || worker::run_rank(&o1, 1, Backend::Uds, &addr));
    let s0 = h0.join().unwrap().unwrap();
    let s1 = h1.join().unwrap().unwrap();

    let reference = worker::run_reference(&opts).unwrap();
    worker::check(&reference, &[s0.clone(), s1.clone()]).unwrap();

    let baseline = worker::run_reference(&worker_opts("topk:10", 4096, 3)).unwrap();
    let (base, cand) = worker::compare_bytes(&baseline, &[s0, s1]).unwrap();
    assert!(cand < base, "measured EF21 traffic {cand} !< baseline {base}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// fault injection: corrupt / truncated / reordered frames, disconnects
// ---------------------------------------------------------------------------

/// Build two consecutive EF21 frames from one sender.
fn two_frames(n: usize) -> (FeedbackState, Vec<u8>, Vec<u8>) {
    let mut sender = FeedbackState::new();
    let (f0, _) = sender.sender_encode(Feedback::Ef21, 0, &randvec(n, 1), 0.1).unwrap();
    let (f1, _) = sender.sender_encode(Feedback::Ef21, 1, &randvec(n, 2), 0.1).unwrap();
    (sender, f0, f1)
}

#[test]
fn corrupt_and_truncated_frames_over_real_socket_are_typed() {
    let n = 256;
    let (_, f0, _) = two_frames(n);
    let mut net = RealTransport::loopback(
        1,
        Backend::Uds,
        WireModel::datacenter(),
        Duration::from_secs(5),
    )
    .unwrap();
    // truncated frame: crosses the socket fine, fails at decode
    net.send(0, Dir::Fwd, 0, Payload::Bytes(&f0[..f0.len() - 3]), 1024, 0.0).unwrap();
    // corrupted feedback tag
    let mut bad = f0.clone();
    bad[5] = 0x7e;
    net.send(0, Dir::Fwd, 1, Payload::Bytes(&bad), 1024, 0.0).unwrap();
    // flipped payload byte: structurally valid, digest must catch it
    let mut flipped = f0.clone();
    let at = flipped.len() - 2;
    flipped[at] ^= 0x40;
    net.send(0, Dir::Fwd, 2, Payload::Bytes(&flipped), 1024, 0.0).unwrap();

    let mut mirror = FeedbackState::new();
    for (key, expect_decode_err) in [(0u64, true), (1, true), (2, false)] {
        let frame = net.recv(0, Dir::Fwd, key).unwrap();
        let payload = frame.payload.as_deref().unwrap();
        match wire::decode_delta(payload) {
            Err(_) => assert!(expect_decode_err, "key {key}: unexpected decode error"),
            Ok(df) => {
                assert!(!expect_decode_err, "key {key}: decode should have failed");
                match mirror.apply_frame(Feedback::Ef21, &df, n) {
                    Err(FeedbackError::DigestMismatch { .. }) => {}
                    other => panic!("want digest mismatch, got {other:?}"),
                }
            }
        }
    }
    // no silent state skew: every injected fault left the mirror virgin
    assert_eq!(mirror.gen(), 0);
    assert!(mirror.global().is_none());
    net.shutdown().unwrap();
}

#[test]
fn reordered_frames_surface_generation_skew_on_both_transports() {
    let n = 128;
    let run = |net: &mut dyn Transport, f0: &[u8], f1: &[u8]| {
        net.send(0, Dir::Fwd, 0, Payload::Bytes(f0), 1024, 0.0).unwrap();
        net.send(0, Dir::Fwd, 1, Payload::Bytes(f1), 1024, 0.0).unwrap();
        let mut mirror = FeedbackState::new();
        // ask for the second message first: keyed mailboxes allow it,
        // the protocol's generation counter refuses it
        let m1 = net.recv(0, Dir::Fwd, 1).unwrap();
        let b1 = m1.payload.clone().unwrap_or_else(|| f1.to_vec());
        let df1 = wire::decode_delta(&b1).unwrap();
        match mirror.apply_frame(Feedback::Ef21, &df1, n) {
            Err(FeedbackError::GenerationSkew { expected: 0, got: 1 }) => {}
            other => panic!("want generation skew, got {other:?}"),
        }
        assert!(mirror.global().is_none(), "skew must not touch the mirror");
        // in-order replay recovers without error
        let m0 = net.recv(0, Dir::Fwd, 0).unwrap();
        let b0 = m0.payload.clone().unwrap_or_else(|| f0.to_vec());
        let df0 = wire::decode_delta(&b0).unwrap();
        mirror.apply_frame(Feedback::Ef21, &df0, n).unwrap();
        mirror.apply_frame(Feedback::Ef21, &df1, n).unwrap();
        assert_eq!(mirror.gen(), 2);
    };
    let (_, f0, f1) = two_frames(n);
    let mut sim = SimNet::new(1, WireModel::datacenter());
    run(&mut sim, &f0, &f1);
    let mut real = RealTransport::loopback(
        1,
        Backend::Tcp,
        WireModel::datacenter(),
        Duration::from_secs(5),
    )
    .unwrap();
    run(&mut real, &f0, &f1);
    real.shutdown().unwrap();
}

#[test]
fn mid_stream_disconnect_is_typed_and_leaves_mirror_consistent() {
    let n = 64;
    let (_, f0, _) = two_frames(n);
    let mut net = RealTransport::loopback(
        1,
        Backend::Uds,
        WireModel::datacenter(),
        Duration::from_secs(5),
    )
    .unwrap();
    net.send(0, Dir::Fwd, 0, Payload::Bytes(&f0), 1024, 0.0).unwrap();
    // the peer dies after one frame: delivered frames stay readable,
    // the missing one is a typed disconnect, and the mirror holds a
    // consistent prefix of the stream (gen 1, digest-verified)
    let mut mirror = FeedbackState::new();
    let frame = net.recv(0, Dir::Fwd, 0).unwrap();
    let df = wire::decode_delta(frame.payload.as_deref().unwrap()).unwrap();
    mirror.apply_frame(Feedback::Ef21, &df, n).unwrap();
    net.shutdown().unwrap();
    match net.recv(0, Dir::Fwd, 1) {
        Err(TransportError::Disconnected { link: 0, .. }) => {}
        other => panic!("want typed disconnect, got {other:?}"),
    }
    assert_eq!(mirror.gen(), 1, "mirror keeps the verified prefix");
    assert!(mirror.global().is_some());
}

#[test]
fn simnet_timeout_on_missing_delta_frame_is_typed() {
    // on the simulator a frame that was never sent is a typed Timeout;
    // the mirror is never consulted, so there is nothing to skew
    let mut sim = SimNet::new(1, WireModel::datacenter());
    match sim.recv(0, Dir::Bwd, 9) {
        Err(TransportError::Timeout { link: 0, dir: Dir::Bwd, key: 9 }) => {}
        other => panic!("want typed timeout, got {other:?}"),
    }
}

#[test]
fn worker_surfaces_mirror_errors_not_panics() {
    // a worker run whose stream is fine must pass; sabotaging the spec
    // mid-run is impossible from outside, but a shared-index spec (the
    // one stateful mode the synthetic worker cannot model) must be a
    // clean error, not a panic
    let opts = worker_opts("topk:10:shared", 64, 1);
    let err = worker::run_reference(&opts).unwrap_err();
    assert!(err.to_string().contains("shared-index"), "{err}");
}
