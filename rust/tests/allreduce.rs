//! Hybrid-DP compressed ring-allreduce battery.
//!
//! Pins the PR's acceptance contracts end to end:
//!
//! * **Bit-parity vs the sequential reference** — rings driven hop by
//!   hop over real UDS sockets produce means bit-identical to
//!   [`allreduce::run_in_memory`], across dp ∈ {2, 4, 8} and every
//!   feedback mode, with EF21 state persisting across optimizer steps.
//! * **Schedule coverage** — the worker harness's allreduce phase keeps
//!   its `--reference`/`--check` mailbox parity over GPipe, 1F1B, and
//!   interleaved v=2, dp up to 8.
//! * **Fault injection** — truncated, misrouted, wrong-segment, and
//!   duplicated tag-5 frames surface as typed [`AllreduceError`]s and
//!   leave accumulators and EF21 mirrors untouched (the run recovers to
//!   the bit-exact clean result); `SimNet` fault models shift arrival
//!   times only; real UDP loopback at 5% datagram loss stays
//!   bit-identical to the clean reference.
//! * **dp = 1 is free** — the hybrid simulator degenerates to the plain
//!   pipeline report and a dp=1 worker run carries zero allreduce
//!   frames.

use std::sync::Mutex;
use std::time::Duration;

use mpcomp::compression::{wire, Spec};
use mpcomp::config::{Schedule, WireOpts};
use mpcomp::coordinator::allreduce::{self, AllreduceError, ReplicaRing};
use mpcomp::coordinator::worker::{self, WorkerOpts};
use mpcomp::coordinator::{pipeline, simexec};
use mpcomp::netsim::{
    Backend, Dir, FaultModel, Payload, RealTransport, SimNet, Transport, WireModel,
};
use mpcomp::util::rng::Rng;

/// `UdpFaults::from_env` knobs are process-global; serialize the tests
/// that set them (same discipline as `tests/lossy_wire.rs`).
static ENV_LOCK: Mutex<()> = Mutex::new(());

struct EnvFaults;

impl EnvFaults {
    fn set(drop_p: f64, seed: u64) -> EnvFaults {
        std::env::set_var("MPCOMP_UDP_DROP_P", drop_p.to_string());
        std::env::set_var("MPCOMP_UDP_FAULT_SEED", seed.to_string());
        EnvFaults
    }
}

impl Drop for EnvFaults {
    fn drop(&mut self) {
        std::env::remove_var("MPCOMP_UDP_DROP_P");
        std::env::remove_var("MPCOMP_UDP_FAULT_SEED");
    }
}

fn rings(dp: usize, elems: usize, mode: &str) -> Vec<ReplicaRing> {
    let spec = Spec::parse(mode).unwrap();
    (0..dp).map(|r| ReplicaRing::new(dp, r, elems, spec).unwrap()).collect()
}

/// One synthetic per-replica gradient per round, keyed exactly like the
/// worker's per-replica PCG32 streams: disjoint `(seed, replica, round)`.
fn round_grads(dp: usize, elems: usize, seed: u64, round: u64) -> Vec<Vec<f32>> {
    (0..dp)
        .map(|r| {
            let mut g = vec![0.0f32; elems];
            Rng::with_stream(seed, (r as u64) << 32 | round).fill_normal(&mut g, 0.0, 1.0);
            g
        })
        .collect()
}

fn bit_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Drive `dp` ring members through one allreduce over a transport:
/// replica `r`'s hop rides link `r` forward, every member sends its
/// step frame before any blocks on its upstream recv (the worker's
/// deadlock-free ring discipline).
fn run_transported(
    rings: &mut [ReplicaRing],
    grads: &[Vec<f32>],
    net: &mut dyn Transport,
    round: usize,
) -> Vec<Vec<f32>> {
    let dp = rings.len();
    for (ring, g) in rings.iter_mut().zip(grads) {
        ring.load(g).unwrap();
    }
    let num_steps = 2 * (dp - 1);
    for step in 0..num_steps {
        let key = (round * num_steps + step) as u64;
        for r in 0..dp {
            let buf = rings[r].make_frame(step).unwrap();
            net.send(r, Dir::Fwd, key, Payload::Bytes(&buf), buf.len(), 0.0).unwrap();
        }
        for r in 0..dp {
            let upstream = (r + dp - 1) % dp;
            let f = net.recv(upstream, Dir::Fwd, key).unwrap();
            let buf = f.payload.expect("real transports carry payloads");
            rings[r].apply_frame(step, &buf).unwrap();
        }
    }
    rings.iter_mut().map(|r| r.finish().unwrap()).collect()
}

// ---------------------------------------------------------------------------
// transported rings == the sequential in-memory reference, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn uds_transported_ring_is_bit_identical_to_the_sequential_reference() {
    for dp in [2usize, 4, 8] {
        for mode in
            ["none", "quant:fw8-bw8", "topk:30", "ef+topk:30", "ef21+topk:10", "aqsgd+topk:30"]
        {
            let elems = 96;
            let mut net = RealTransport::loopback(
                dp,
                Backend::Uds,
                WireModel::datacenter(),
                Duration::from_secs(10),
            )
            .unwrap();
            let mut wired = rings(dp, elems, mode);
            let mut reference = rings(dp, elems, mode);
            // two optimizer steps: EF21 segment mirrors and AQ-SGD
            // buffers must persist (and stay in lockstep) across rounds
            for round in 0..2usize {
                let grads = round_grads(dp, elems, 7, round as u64);
                let wire_out = run_transported(&mut wired, &grads, &mut net, round);
                let ref_out = allreduce::run_in_memory(&mut reference, &grads).unwrap();
                for r in 0..dp {
                    assert!(
                        bit_eq(&wire_out[r], &ref_out[r]),
                        "{mode} dp={dp} round={round}: replica {r} diverged from reference"
                    );
                }
                for r in 1..dp {
                    assert!(
                        bit_eq(&wire_out[0], &wire_out[r]),
                        "{mode} dp={dp} round={round}: replica {r} not bit-identical"
                    );
                }
            }
            net.shutdown().unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// worker harness: mailbox parity across schedules, dp, feedback modes
// ---------------------------------------------------------------------------

fn worker_opts(stages: usize, schedule: Schedule, mode: &str) -> WorkerOpts {
    WorkerOpts {
        stages,
        // interleaved schedules want mb divisible by the rank count
        mb: stages.max(4),
        link_elems: 64,
        schedule,
        spec: Spec::parse(mode).unwrap(),
        plan: None,
        seed: 23,
        wire: WireOpts {
            profile: "datacenter".into(),
            recv_timeout_s: 10.0,
            ..WireOpts::default()
        },
        steps: 2,
        dp: stages,
    }
}

#[test]
fn worker_allreduce_parity_across_dp_schedules_and_feedback() {
    // dp == stages in the worker harness; flat chains carry the wrap
    // hop only at 2 ranks, deeper rings need the interleaved topology
    let shapes = [
        (2usize, Schedule::GPipe),
        (2, Schedule::OneFOneB),
        (2, Schedule::Interleaved { v: 2 }),
        (4, Schedule::Interleaved { v: 2 }),
        (8, Schedule::Interleaved { v: 2 }),
    ];
    for &(dp, schedule) in &shapes {
        for mode in ["none", "quant:fw8-bw6", "topk:10", "ef21+topk:10"] {
            let opts = worker_opts(dp, schedule, mode);
            let reference = worker::run_reference(&opts)
                .unwrap_or_else(|e| panic!("dp={dp} {schedule:?} {mode}: {e}"));
            // every hop mailbox logged high-bit allreduce keys: the
            // phase genuinely ran, 2*(dp-1) steps x 2 rounds of them
            let ar_frames: usize = reference
                .boxes
                .iter()
                .flat_map(|b| &b.recv)
                .filter(|(k, _, _)| k & (1 << 63) != 0)
                .count();
            assert_eq!(
                ar_frames,
                dp * 2 * (dp - 1) * opts.steps,
                "dp={dp} {schedule:?} {mode}: allreduce frame count"
            );
            let loopback = worker::run_loopback(&opts, Backend::Uds)
                .unwrap_or_else(|e| panic!("dp={dp} {schedule:?} {mode}: {e}"));
            worker::check(&reference, std::slice::from_ref(&loopback))
                .unwrap_or_else(|e| panic!("dp={dp} {schedule:?} {mode}: {e}"));
        }
    }
}

// ---------------------------------------------------------------------------
// typed faults leave state untouched; the run recovers bit-exactly
// ---------------------------------------------------------------------------

#[test]
fn faulty_frames_are_typed_and_the_run_recovers_bit_exactly() {
    let (dp, elems, mode) = (4usize, 128, "ef21+topk:10");
    let mut clean = rings(dp, elems, mode);
    let mut faulted = rings(dp, elems, mode);
    for round in 0..2u64 {
        let grads = round_grads(dp, elems, 31, round);
        let want = allreduce::run_in_memory(&mut clean, &grads).unwrap();

        // same round on the faulted rings, but replica 0 sees a fault
        // cocktail before every real frame
        for (ring, g) in faulted.iter_mut().zip(&grads) {
            ring.load(g).unwrap();
        }
        for step in 0..2 * (dp - 1) {
            let frames: Vec<Vec<u8>> =
                faulted.iter_mut().map(|r| r.make_frame(step).unwrap()).collect();
            for r in 0..dp {
                let from = (r + dp - 1) % dp;
                let frame = &frames[from];
                if r == 0 {
                    let mirrors_before = faulted[0].memory_bytes();
                    // truncation -> typed codec error
                    let err = faulted[0].apply_frame(step, &frame[..frame.len() - 3]).unwrap_err();
                    assert!(matches!(err, AllreduceError::Codec { .. }), "step {step}: {err}");
                    // reordered hop (wrong step coordinates) -> misrouted
                    let (meta, inner) = wire::decode_allreduce(frame).unwrap();
                    let wrong = wire::encode_allreduce(meta.phase, meta.step + 5, meta.seg, inner);
                    let err = faulted[0].apply_frame(step, &wrong).unwrap_err();
                    assert!(matches!(err, AllreduceError::Misrouted { .. }), "step {step}: {err}");
                    // right envelope, undersized payload -> segment size
                    let stub = wire::encode_allreduce(
                        meta.phase,
                        meta.step,
                        meta.seg,
                        &wire::encode_raw(&[0.0; 3]),
                    );
                    let err = faulted[0].apply_frame(step, &stub).unwrap_err();
                    assert!(
                        matches!(err, AllreduceError::SegmentSize { expected: _, got: 3 }),
                        "step {step}: {err}"
                    );
                    assert_eq!(
                        faulted[0].memory_bytes(),
                        mirrors_before,
                        "step {step}: rejected frames must not grow feedback mirrors"
                    );
                }
                faulted[r].apply_frame(step, frame).unwrap();
                if r == 0 && step < dp - 1 {
                    // duplicated delivery (UDP dup) of a reduce-scatter
                    // delta frame: the EF21 generation counter refuses
                    // the re-apply, so the partial sum is never doubled
                    let err = faulted[0].apply_frame(step, frame).unwrap_err();
                    assert!(
                        matches!(err, AllreduceError::Feedback(_)),
                        "step {step}: duplicate delta frame must be refused, got {err}"
                    );
                }
            }
        }
        let got: Vec<Vec<f32>> = faulted.iter_mut().map(|r| r.finish().unwrap()).collect();
        for r in 0..dp {
            assert!(
                bit_eq(&got[r], &want[r]),
                "round {round}: replica {r} diverged after surviving the fault cocktail"
            );
        }
    }
}

#[test]
fn simnet_faults_shift_allreduce_timing_but_never_the_result() {
    let (dp, elems, mode) = (4usize, 96, "topk:30");
    let grads = round_grads(dp, elems, 57, 0);

    let drive = |net: &mut SimNet| -> (Vec<Vec<f32>>, Vec<f64>) {
        let mut rs = rings(dp, elems, mode);
        for (ring, g) in rs.iter_mut().zip(&grads) {
            ring.load(g).unwrap();
        }
        let mut arrivals = Vec::new();
        for step in 0..2 * (dp - 1) {
            let frames: Vec<Vec<u8>> = rs.iter_mut().map(|r| r.make_frame(step).unwrap()).collect();
            for (r, frame) in frames.iter().enumerate() {
                net.send_to(r, Dir::Fwd, step as u64, frame.len(), frame.len(), 0.0);
            }
            for r in 0..dp {
                let from = (r + dp - 1) % dp;
                let m = net.try_recv(from, Dir::Fwd, step as u64).expect("hop delivered");
                arrivals.push(m.arrival);
                // the simulator keeps tensors in-process: the protocol
                // replays the sender-side frame, faults price time only
                rs[r].apply_frame(step, &frames[from]).unwrap();
            }
        }
        (rs.iter_mut().map(|r| r.finish().unwrap()).collect(), arrivals)
    };

    let mut clean_net = SimNet::new(dp, WireModel::wan());
    let (clean_out, clean_arrivals) = drive(&mut clean_net);
    let mut lossy_net = SimNet::new(dp, WireModel::wan()).with_faults(FaultModel {
        drop_p: 0.05,
        dup_p: 0.05,
        reorder_window: 2,
        seed: 41,
        ..FaultModel::default()
    });
    let (lossy_out, lossy_arrivals) = drive(&mut lossy_net);

    for r in 0..dp {
        assert!(bit_eq(&clean_out[r], &lossy_out[r]), "replica {r}: faults changed the math");
    }
    let mut slipped = 0;
    for (c, l) in clean_arrivals.iter().zip(&lossy_arrivals) {
        assert!(l >= c, "faults can only delay arrivals ({l} < {c})");
        if l > c {
            slipped += 1;
        }
    }
    assert!(slipped > 0, "5% loss + reorder must delay at least one hop");
}

#[test]
fn udp_loopback_allreduce_parity_under_five_percent_loss() {
    let _g = ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let _env = EnvFaults::set(0.05, 0x5eed);
    let mut opts = worker_opts(2, Schedule::GPipe, "ef21+topk:10");
    opts.link_elems = 256;
    opts.steps = 3;
    let reference = worker::run_reference(&opts).unwrap();
    let real = worker::run_loopback(&opts, Backend::Udp).unwrap();
    worker::check(&reference, std::slice::from_ref(&real)).unwrap();
}

// ---------------------------------------------------------------------------
// dp = 1 degenerates to the plain pipeline, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn dp1_hybrid_simulation_is_the_plain_pipeline_report() {
    let stages = 4;
    let nb = pipeline::num_boundaries(stages, 1);
    let elems = 16_384;
    let spec = Spec::parse("topk:10").unwrap();
    let (fb, bb) = simexec::spec_wire_bytes(&spec, elems);
    let pp = simexec::SimSpec {
        n_stages: stages,
        v: 1,
        n_mb: 8,
        fwd_op_s: 0.020,
        bwd_op_s: 0.040,
        recompute_s: 0.0,
        fwd_bytes: vec![fb; nb],
        bwd_bytes: vec![bb; nb],
        raw_bytes: vec![wire::raw_wire_bytes(elems); nb],
        model: WireModel::wan(),
        capacity: 4,
        faults: None,
    };
    let ops = pipeline::ops_for(Schedule::OneFOneB, stages, 8).unwrap();
    let plain = simexec::simulate(&ops, &pp);
    let hybrid = simexec::simulate_hybrid(
        &ops,
        &simexec::HybridSpec { pp, dp: 1, grad_elems: 1 << 20, grad_spec: spec },
    );
    assert_eq!(plain.makespan_s.to_bits(), hybrid.makespan_s.to_bits());
    assert_eq!(plain.bytes, hybrid.bytes);
    assert_eq!(plain.raw_bytes, hybrid.raw_bytes);
    assert_eq!(plain.busy_s.to_bits(), hybrid.busy_s.to_bits());
}

#[test]
fn dp1_worker_run_ships_zero_allreduce_frames() {
    let mut opts = worker_opts(2, Schedule::GPipe, "ef21+topk:10");
    opts.dp = 1;
    let reference = worker::run_reference(&opts).unwrap();
    let loopback = worker::run_loopback(&opts, Backend::Uds).unwrap();
    worker::check(&reference, std::slice::from_ref(&loopback)).unwrap();
    for summary in [&reference, &loopback] {
        let ar = summary
            .boxes
            .iter()
            .flat_map(|b| &b.recv)
            .filter(|(k, _, _)| k & (1 << 63) != 0)
            .count();
        assert_eq!(ar, 0, "dp=1 must not touch the allreduce key space");
    }
}
