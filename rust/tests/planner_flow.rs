//! End-to-end planner flow: the search's emitted plan survives the
//! file roundtrip, drives `mpcomp worker`-style runs with per-channel
//! specs, and its frames cross real sockets bit-identically to the
//! SimNet reference — the artifact path CI's negotiated-plan lane runs
//! across two OS processes.

use mpcomp::config::{Schedule, WireOpts};
use mpcomp::coordinator::worker::{self, WorkerOpts};
use mpcomp::netsim::{Backend, WireModel};
use mpcomp::planner::{search, Plan, PlannerInputs};

fn small_inputs() -> PlannerInputs {
    PlannerInputs {
        n_ranks: 2,
        schedule: Schedule::Interleaved { v: 2 },
        n_mb: 4,
        fwd_op_s: 0.010,
        bwd_op_s: 0.020,
        recompute_s: 0.0,
        elems: vec![4096; 3],
        model: WireModel::wan(),
        capacity: 4,
        faults: None,
    }
}

fn worker_opts_with(plan: Plan) -> WorkerOpts {
    WorkerOpts {
        stages: 2,
        mb: 4,
        link_elems: 4096,
        schedule: Schedule::Interleaved { v: 2 },
        spec: mpcomp::compression::Spec::none(),
        plan: Some(plan),
        seed: 23,
        wire: WireOpts {
            profile: "datacenter".into(),
            recv_timeout_s: 10.0,
            ..WireOpts::default()
        },
        steps: 2,
        dp: 1,
    }
}

#[test]
fn searched_plan_roundtrips_and_drives_the_worker() {
    let report = search(&small_inputs()).unwrap();
    let path = std::env::temp_dir().join(format!("mpcomp-flow-{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    report.plan.save(&path).unwrap();
    let loaded = Plan::load(&path).unwrap();
    assert_eq!(loaded, report.plan);
    assert_eq!(loaded.digest(), report.plan.digest());
    let _ = std::fs::remove_file(&path);

    // the loaded plan keys the worker's channel codecs: deterministic
    // reference, and the loopback real transport matches it bit for bit
    let opts = worker_opts_with(loaded);
    let a = worker::run_reference(&opts).unwrap();
    let b = worker::run_reference(&opts).unwrap();
    assert_eq!(a.boxes, b.boxes);
    let real = worker::run_loopback(&opts, Backend::Uds).unwrap();
    worker::check(&a, &[real]).unwrap();
}

#[test]
fn wan_search_on_the_small_ring_is_wire_bound_and_beats_globals() {
    // the acceptance property holds on the small shape too (the pinned
    // 4x16 claim lives in planner::search tests and exp plan)
    let report = search(&small_inputs()).unwrap();
    assert!(report.wire_bound);
    for b in &report.baselines {
        assert!(
            report.sim_makespan_s < b.sim_makespan_s,
            "plan {} !< '{}' {}",
            report.sim_makespan_s,
            b.label,
            b.sim_makespan_s
        );
    }
    report.plan.validate_for(2, 2, 4).unwrap();
}
