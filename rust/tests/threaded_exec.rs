//! Threaded-executor integration tests (`exec = threaded`).
//!
//! The first half needs nothing but loopback sockets: the worker
//! harness's schedule run with one OS thread per rank over ports of a
//! shared stream transport must be bit-identical — per-mailbox delivery
//! order, byte counts, payload digests — to the single-process `SimNet`
//! reference, on every schedule (interleaved v=2 ring included) and
//! under the error-feedback delta protocols. A property test sweeps
//! shapes and specs; the per-process loopback runner is cross-checked
//! too, so all three executors agree.
//!
//! The second half (artifacts-gated, like `tests/integration.rs`)
//! asserts the trainer-level guarantee: training with `exec = threaded`
//! over real UDS sockets produces bit-identical parameters and
//! identical per-link byte counts to the sequential `SimNet` run.

use mpcomp::compression::Spec;
use mpcomp::config::{CompressImpl, ExecMode, Schedule, TrainConfig, WireOpts};
use mpcomp::coordinator::worker::{self, WorkerOpts};
use mpcomp::coordinator::{run_threaded, Trainer};
use mpcomp::netsim::Backend;
use mpcomp::runtime::Runtime;
use mpcomp::tensor::Tensor;
use mpcomp::util::prop::run_prop;

fn worker_opts(stages: usize, mb: usize, link_elems: usize, mode: &str, seed: u64) -> WorkerOpts {
    WorkerOpts {
        stages,
        mb,
        link_elems,
        schedule: Schedule::GPipe,
        spec: Spec::parse(mode).unwrap(),
        plan: None,
        seed,
        wire: WireOpts {
            profile: "datacenter".into(),
            recv_timeout_s: 10.0,
            ..WireOpts::default()
        },
        steps: 1,
        dp: 1,
    }
}

#[test]
fn prop_threaded_matches_sim_mailboxes() {
    // Shape/spec sweep of the core contract: thread-per-rank execution
    // over shared uds sockets delivers exactly what the ordered SimNet
    // replay delivers — feedback mirrors included, whose generation
    // counters turn any cross-thread reordering into a typed error.
    run_prop("threaded mailboxes == sim mailboxes", 6, |g| {
        let stages = g.usize(2, 3);
        let mb = g.usize(1, 4);
        let elems = g.usize(8, 200);
        let mode =
            *g.choose(&["none", "topk:10", "quant:fw4-bw6", "ef21+topk:10", "aqsgd+topk:30"]);
        let mut opts = worker_opts(stages, mb, elems, mode, g.usize(0, 1 << 20) as u64);
        opts.steps = g.usize(1, 2);
        if g.bool() {
            opts.schedule = Schedule::OneFOneB;
        }
        let reference = worker::run_reference(&opts).map_err(|e| e.to_string())?;
        let threaded = run_threaded(&opts, Backend::Uds).map_err(|e| e.to_string())?;
        worker::check(&reference, &[threaded]).map_err(|e| e.to_string())
    });
}

#[test]
fn threaded_interleaved_ring_matches_reference() {
    // v=2 ring: two rank threads, each hosting two chunks, sharing the
    // wrap link concurrently — still bit-identical to the reference.
    for mode in ["topk:10", "ef21+topk:10"] {
        let mut opts = worker_opts(2, 4, 256, mode, 11);
        opts.schedule = Schedule::Interleaved { v: 2 };
        opts.steps = 2;
        let reference = worker::run_reference(&opts).unwrap();
        let threaded = run_threaded(&opts, Backend::Uds).unwrap();
        worker::check(&reference, &[threaded]).unwrap_or_else(|e| panic!("{mode}: {e}"));
    }
}

#[test]
fn threaded_tcp_matches_sequential_loopback() {
    // All three executors agree: SimNet reference, sequential loopback
    // (one thread driving every rank), and thread-per-rank — over TCP.
    let opts = worker_opts(3, 4, 128, "quant:fw8-bw8", 23);
    let reference = worker::run_reference(&opts).unwrap();
    let sequential = worker::run_loopback(&opts, Backend::Tcp).unwrap();
    let threaded = run_threaded(&opts, Backend::Tcp).unwrap();
    worker::check(&reference, &[sequential, threaded]).unwrap();
}

#[test]
fn threaded_rejects_single_endpoint_backends() {
    let opts = worker_opts(2, 2, 64, "none", 1);
    for backend in [Backend::Sim, Backend::Udp] {
        let err = run_threaded(&opts, backend).unwrap_err().to_string();
        assert!(err.contains("stream backend"), "{backend:?}: {err}");
    }
}

#[test]
fn trainer_rejects_threaded_on_non_stream_backend() {
    // Trainer::new validates exec/backend compatibility up front — a
    // typed error at construction, not a deadlocked epoch later.
    if !artifacts_ready() {
        return;
    }
    let mut cfg = tiny_cfg();
    cfg.exec = ExecMode::Threaded;
    for backend in ["sim", "udp"] {
        cfg.backend = backend.into();
        let rt = Runtime::from_dir(&cfg.artifacts_dir).expect("loading artifacts");
        let err = Trainer::new(rt, cfg.clone()).expect_err("threaded over sim must be rejected");
        assert!(err.to_string().contains("stream backend"), "{backend}: {err:#}");
    }
}

// ---------------------------------------------------------------------------
// trainer-level (artifacts-gated): threaded == sequential, bit for bit
// ---------------------------------------------------------------------------

fn artifacts_ready() -> bool {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let ok = std::path::Path::new(dir).join("manifest.json").exists();
    if !ok {
        eprintln!("artifacts not built; skipping integration test");
    }
    ok
}

fn tiny_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::defaults("cnn16");
    cfg.artifacts_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into();
    cfg.results_dir = std::env::temp_dir().join("mpcomp_threadedtest").to_str().unwrap().into();
    cfg.train_size = 200;
    cfg.test_size = 100;
    cfg.epochs = 1;
    cfg.lr0 = 0.05;
    cfg.compress_impl = CompressImpl::Native;
    cfg.sim_op_time = Some(0.020);
    cfg
}

fn run_once(cfg: TrainConfig) -> (Vec<Vec<Tensor>>, u64) {
    let rt = Runtime::from_dir(&cfg.artifacts_dir).expect("loading artifacts");
    let mut trainer = Trainer::new(rt, cfg).unwrap();
    let m = trainer.run().unwrap();
    (trainer.stage_params(), m.wire_bytes)
}

#[test]
fn threaded_training_is_bit_identical_to_sequential() {
    // The tentpole guarantee: one epoch trained with one OS thread per
    // rank over real UDS sockets yields bit-identical parameters and
    // identical per-link byte accounting to the sequential SimNet run.
    // Single ordered writers everywhere (stages, link feedback state,
    // the loss sum) make this exact, not approximate.
    if !artifacts_ready() {
        return;
    }
    for mode in ["none", "topk:10"] {
        let mut base = tiny_cfg();
        base.spec = Spec::parse(mode).unwrap();
        let (p_seq, bytes_seq) = run_once(base.clone());
        let mut thr = base.clone();
        thr.backend = "uds".into();
        thr.exec = ExecMode::Threaded;
        let (p_thr, bytes_thr) = run_once(thr);
        for (a, b) in p_seq.iter().flatten().zip(p_thr.iter().flatten()) {
            assert_eq!(a.data(), b.data(), "{mode}: sequential vs threaded diverged");
        }
        assert_eq!(bytes_seq, bytes_thr, "{mode}: byte accounting diverged");
    }
}

#[test]
fn threaded_training_1f1b_matches_sequential_uds() {
    // Same-backend comparison (uds vs uds) on the 1F1B schedule: the
    // only variable is the executor.
    if !artifacts_ready() {
        return;
    }
    let mut base = tiny_cfg();
    base.spec = Spec::parse("quant:fw8-bw8").unwrap();
    base.schedule = Schedule::OneFOneB;
    base.backend = "uds".into();
    let (p_seq, bytes_seq) = run_once(base.clone());
    let mut thr = base;
    thr.exec = ExecMode::Threaded;
    let (p_thr, bytes_thr) = run_once(thr);
    for (a, b) in p_seq.iter().flatten().zip(p_thr.iter().flatten()) {
        assert_eq!(a.data(), b.data(), "sequential-uds vs threaded-uds diverged");
    }
    assert_eq!(bytes_seq, bytes_thr);
}
