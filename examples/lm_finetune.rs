//! LM fine-tuning scenario (the paper's GPT-2/Wikitext setup, §3.2):
//! pretrain the staged decoder uncompressed, then fine-tune with TopK
//! compression — comparing shared-index vs independent activation/
//! gradient compression (the paper's Table 5 divergence finding).
//!
//! ```bash
//! cargo run --release --example lm_finetune
//! ```

use anyhow::Result;
use mpcomp::compression::Spec;
use mpcomp::config::TrainConfig;
use mpcomp::coordinator::Trainer;
use mpcomp::runtime::Runtime;

fn base() -> TrainConfig {
    let mut cfg = TrainConfig::defaults("lm128");
    cfg.batch_size = 8;
    cfg.train_size = 200; // sequences
    cfg.test_size = 40;
    cfg.lr0 = 1e-3;
    cfg.cosine_tmax = 1_000_000;
    cfg
}

fn main() -> Result<()> {
    let ckpt = std::env::temp_dir().join("mpcomp_lm_finetune_example.ckpt");
    let ckpt_s = ckpt.to_str().unwrap().to_string();

    // 1. pretrain (the "pretrained GPT-2" of the paper)
    println!("pretraining (uncompressed, 4 epochs)...");
    let mut cfg = base();
    cfg.epochs = 4;
    cfg.save_checkpoint = Some(ckpt_s.clone());
    let rt = Runtime::from_dir(&cfg.artifacts_dir)?;
    let mut trainer = Trainer::new(rt, cfg)?;
    let pre = trainer.run()?;
    println!(
        "  pretrained eval loss {:.3} (ppl {:.1})\n",
        pre.final_eval_off(),
        pre.final_eval_off().exp()
    );
    drop(trainer);

    // 2. fine-tune under compression
    println!(
        "{:<22} {:>10} {:>12} {:>12}",
        "fine-tune mode", "eval loss", "perplexity", "wire ratio"
    );
    for mode in ["none", "topk:30:shared", "topk:10:shared", "topk:10:separate"] {
        let mut cfg = base();
        cfg.epochs = 2;
        cfg.spec = Spec::parse(mode)?;
        cfg.init_checkpoint = Some(ckpt_s.clone());
        let rt = Runtime::from_dir(&cfg.artifacts_dir)?;
        let mut trainer = Trainer::new(rt, cfg)?;
        let m = trainer.run()?;
        let loss = m.final_eval_on();
        println!(
            "{:<22} {:>10.3} {:>12.2} {:>11.1}x",
            mode,
            loss,
            loss.exp(),
            m.wire_raw_bytes as f64 / m.wire_bytes.max(1) as f64
        );
    }
    println!("\n(expected shape: the LM tolerates far less sparsification than the\n\
              CNN, and independent indices hurt much more than shared indices)");
    std::fs::remove_file(ckpt).ok();
    Ok(())
}
