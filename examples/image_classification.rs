//! Image-classification scenario (the paper's ResNet18/CIFAR-10 setup,
//! §3.1): train the staged CNN under three representative compression
//! regimes and print the paper-style off/on accuracy comparison.
//!
//! ```bash
//! cargo run --release --example image_classification [-- epochs]
//! ```

use anyhow::Result;
use mpcomp::compression::Spec;
use mpcomp::config::TrainConfig;
use mpcomp::coordinator::Trainer;
use mpcomp::runtime::Runtime;

fn main() -> Result<()> {
    let epochs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let mut base = TrainConfig::defaults("cnn16");
    base.epochs = epochs;
    base.train_size = 800;
    base.test_size = 200;
    base.lr0 = 0.05;
    base.cosine_tmax = 2 * epochs;
    base.noise = 0.45;

    println!("CNN image classification, {} epochs / mode\n", epochs);
    println!(
        "{:<18} {:>14} {:>14} {:>10} {:>9}",
        "mode", "acc (comp off)", "acc (comp on)", "wire", "wall"
    );
    for mode in ["none", "quant:fw4-bw8", "quant:fw2-bw6", "topk:10"] {
        let mut cfg = base.clone();
        cfg.spec = Spec::parse(mode)?;
        let rt = Runtime::from_dir(&cfg.artifacts_dir)?;
        let mut trainer = Trainer::new(rt, cfg)?;
        let m = trainer.run()?;
        println!(
            "{:<18} {:>13.1}% {:>13.1}% {:>9.1}x {:>8.1}s",
            mode,
            100.0 * m.best_eval_off(),
            100.0 * m.best_eval_on(),
            m.wire_raw_bytes as f64 / m.wire_bytes.max(1) as f64,
            m.wall_time_s
        );
    }
    println!("\n(expected shape: mild compression tracks the baseline; strong\n\
              activation compression needs compression at inference too)");
    Ok(())
}
