//! End-to-end system driver: trains a pipeline-parallel transformer LM
//! for a few hundred optimizer steps through the full three-layer stack
//! (rust coordinator -> PJRT-compiled JAX stages -> Pallas compression
//! kernels on every link), logging the loss curve, throughput, and
//! communication accounting. Recorded in EXPERIMENTS.md §E2E.
//!
//! Scale note (DESIGN.md §4): the reference scenario is a ~100M-param
//! GPT; this testbed is a single CPU core, so the default preset is the
//! ~0.8M-param staged `lm128`. The same driver runs the larger AOT
//! presets (`python -m compile.aot --models e2e-medium|gpt100m`) on real
//! hardware, unchanged: the coordinator is size-agnostic.
//!
//! ```bash
//! cargo run --release --example e2e_train -- [steps] [model] [mode]
//! # e.g.  cargo run --release --example e2e_train -- 300 lm128 ef21+topk:10
//! ```

use anyhow::Result;
use mpcomp::compression::Spec;
use mpcomp::config::TrainConfig;
use mpcomp::coordinator::Trainer;
use mpcomp::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = args.get(2).cloned().unwrap_or_else(|| "lm128".to_string());
    let mode = args.get(3).cloned().unwrap_or_else(|| "topk:10:shared".to_string());

    let mut cfg = TrainConfig::defaults(&model);
    cfg.spec = Spec::parse(&mode)?;
    cfg.batch_size = 8;
    // size the corpus so one epoch = `steps_per_epoch` optimizer steps
    let steps_per_epoch = 25usize;
    cfg.train_size = steps_per_epoch * cfg.batch_size;
    cfg.test_size = 40;
    cfg.epochs = steps.div_ceil(steps_per_epoch);
    cfg.lr0 = 1e-3;
    cfg.cosine_tmax = 1_000_000;
    cfg.eval_every = 1;

    let rt = Runtime::from_dir(&cfg.artifacts_dir)?;
    let spec = rt.manifest().model(&model)?;
    let params = spec.total_params();
    let seq = spec.meta_usize("seq").unwrap_or(64);
    println!(
        "e2e: model={model} ({params} params, mp_degree={}), {} steps, compression '{}'",
        spec.mp_degree,
        cfg.epochs * steps_per_epoch,
        cfg.spec.label()
    );

    let results_dir = cfg.results_dir.clone();
    let tokens_per_step = (cfg.batch_size * seq) as f64;
    let mut trainer = Trainer::new(rt, cfg)?;
    let m = trainer.run()?;

    println!("\nstep   train_loss   eval_loss(on)   ppl");
    for p in &m.points {
        println!(
            "{:>5}  {:>10.4}  {:>13.4}  {:>6.1}",
            p.step,
            p.train_loss,
            p.eval_on,
            p.eval_on.exp()
        );
    }
    let total_steps = m.points.last().map(|p| p.step).unwrap_or(0);
    println!("\n-- e2e summary --");
    println!("steps:            {total_steps}");
    println!("throughput:       {:.1} tokens/s ({:.2} s/step)",
        tokens_per_step * total_steps as f64 / m.wall_time_s,
        m.wall_time_s / total_steps.max(1) as f64);
    println!("wire sent:        {:.1} MB ({:.1}x compression)",
        m.wire_bytes as f64 / 1e6,
        m.wire_raw_bytes as f64 / m.wire_bytes.max(1) as f64);
    println!("sim wire time:    {:.1} s (100 Mbit/s + 10 ms model); uncompressed would be {:.1} s",
        m.wire_sim_time_s,
        m.wire_sim_time_s * m.wire_raw_bytes as f64 / m.wire_bytes.max(1) as f64);
    println!("wall time:        {:.1} s", m.wall_time_s);

    m.write_csv(&results_dir, "e2e")?;
    println!("loss curve CSV -> {results_dir}/");
    Ok(())
}
