//! Quickstart: train the staged CNN for two epochs with Top10%
//! compression on every pipeline link, then evaluate with and without
//! compression at inference — the paper's core experiment in miniature.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use mpcomp::config::TrainConfig;
use mpcomp::coordinator::Trainer;
use mpcomp::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::from_dir("artifacts")?;

    let mut cfg = TrainConfig::defaults("cnn16");
    cfg.set("compression", "topk:10")?;
    cfg.set("epochs", "2")?;
    cfg.set("train_size", "600")?;
    cfg.set("test_size", "200")?;
    println!("model: {} | compression: {}", cfg.model, cfg.spec.label());

    let mut trainer = Trainer::new(rt, cfg)?;
    let metrics = trainer.run()?;

    println!("\nepoch  train_loss  acc(comp on)  acc(comp off)");
    for p in &metrics.points {
        println!(
            "{:>5}  {:>10.4}  {:>12.1}%  {:>13.1}%",
            p.epoch,
            p.train_loss,
            100.0 * p.eval_on,
            100.0 * p.eval_off
        );
    }
    println!(
        "\nwire: {:.1} MB sent ({}x compression), simulated wire time {:.1}s",
        metrics.wire_bytes as f64 / 1e6,
        (metrics.wire_raw_bytes as f64 / metrics.wire_bytes.max(1) as f64).round(),
        metrics.wire_sim_time_s
    );
    println!("wall time: {:.1}s", metrics.wall_time_s);
    Ok(())
}
